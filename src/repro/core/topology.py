"""Pod topologies for multi-pool fleet simulation (Pond §3 + Octopus).

Pond's pool-size analysis (§3, Fig 3) shows 8-16 socket pods capture
most of the pooling benefit; Octopus (PAPERS.md) goes further and shows
*sparse, overlapping* pod topologies beat partitioned ones at equal
hardware cost, because a server that can reach more than one pod
smooths demand spikes across pods.  This module is the topology layer
for the fleet engines: a :class:`Topology` is a fixed VM->pods
incidence structure — per server, the ordered list of pods it can draw
CXL slices from — plus builders for the three families the fleet study
prices:

* :func:`partitioned` — disjoint pods of ``pod_size`` consecutive
  servers, fanout 1 (the classic Pond pool-group layout; with
  ``pod_size == n_servers`` this is :func:`single_pool`, the degenerate
  topology that must reproduce the single-pool engine bitwise).
* :func:`overlapping` — cyclic Octopus-style overlap: server ``s``
  reaches pods ``(s // pod_size + j) % n_pods`` for ``j < fanout``, so
  adjacent pods share servers and every pod keeps ``pod_size`` primary
  members (equal hardware: the pod count matches the partitioned
  layout, only the reach differs).
* :func:`sparse` — seeded random incidence: every server draws
  ``fanout`` distinct pods uniformly (a pod may end up with ZERO
  members, and with ``allow_orphans=True`` a server may reach no pod
  at all — both degenerate cases the differential suite covers).

**Incidence layout.**  ``inc`` is an ``(n_servers, fanout)`` int32
array; row ``s`` lists the pods server ``s`` can reach *in preference
order* (admission grants the whole pool demand from the FIRST listed
pod with room — one pod per VM, mirroring the one-EMC-group grant of
the single-pool engines), padded with ``-1`` for servers reaching
fewer than ``fanout`` pods.  The compiled sweeps consume this array
directly (padded, one row block per candidate lane); the scalar oracle
``cluster_sim.replay_multi_pool`` walks the same rows in the same
order, which is what makes the bit-exactness contract well defined.

Capacities are per pod, not per topology: :func:`split_pool` splits a
total pool budget into integral per-pod GBs (remainder spread over the
first pods) so fleet candidates at equal total hardware stay in the
integral-GB domain the bit-exact integer sweeps require.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: topology family names (``Topology.kind``)
KINDS = ("partitioned", "overlapping", "sparse", "single")


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fixed server->pods incidence structure.

    ``inc[s]`` lists the pods server ``s`` may draw pool slices from,
    in preference order, ``-1``-padded.  Immutable by convention: the
    engines treat a Topology as compile-time data.
    """

    kind: str
    n_servers: int
    n_pods: int
    fanout: int                 # max pods any server reaches (inc width)
    inc: np.ndarray             # (n_servers, fanout) int32, -1 padded

    def __post_init__(self):
        validate_incidence(self.inc, self.n_pods, self.fanout)
        if self.inc.shape[0] != self.n_servers:
            raise ValueError(
                f"incidence rows {self.inc.shape[0]} != n_servers "
                f"{self.n_servers}")

    # ------------------------------------------------------------ queries --
    def pods_of(self, s: int) -> list[int]:
        """Reachable pods of server ``s``, in preference order."""
        row = self.inc[s]
        return [int(q) for q in row if q >= 0]

    def members(self, pod: int) -> list[int]:
        """Servers that can reach ``pod`` (may be empty — a pod with
        zero members is legal and simply never grants)."""
        return [int(s) for s in
                np.flatnonzero((self.inc == pod).any(axis=1))]

    def describe(self) -> str:
        return (f"{self.kind}(servers={self.n_servers}, "
                f"pods={self.n_pods}, fanout={self.fanout})")


def validate_incidence(inc: np.ndarray, n_pods: int,
                       fanout: int) -> None:
    """Raise ``ValueError`` unless ``inc`` is a valid incidence matrix:
    int array, width <= fanout, entries in ``[-1, n_pods)``, no
    duplicate pod within a row, and ``-1`` padding only at the tail of
    each row (preference order must be contiguous)."""
    inc = np.asarray(inc)
    if inc.ndim != 2 or not np.issubdtype(inc.dtype, np.integer):
        raise ValueError("incidence must be a 2-D integer array")
    if inc.shape[1] > max(fanout, 1):
        raise ValueError(
            f"incidence width {inc.shape[1]} exceeds fanout {fanout}")
    if inc.size and (inc.min() < -1 or inc.max() >= n_pods):
        raise ValueError(
            f"incidence entries must lie in [-1, {n_pods}); got range "
            f"[{inc.min()}, {inc.max()}]")
    for s in range(inc.shape[0]):
        row = inc[s]
        real = row[row >= 0]
        if len(np.unique(real)) != len(real):
            raise ValueError(f"server {s} lists a pod twice: {row}")
        # -1 padding must be a suffix, or "first pod with room" would
        # skip over holes differently in the oracle and the kernel
        seen_pad = False
        for q in row:
            if q < 0:
                seen_pad = True
            elif seen_pad:
                raise ValueError(
                    f"server {s} has interior -1 padding: {row}")


# ---------------------------------------------------------------- builders --
def partitioned(n_servers: int, pod_size: int) -> Topology:
    """Disjoint pods of ``pod_size`` consecutive servers (fanout 1).

    The last pod may be ragged.  ``partitioned(n, n)`` is the 1-pod
    degenerate (see :func:`single_pool`).
    """
    if pod_size < 1:
        raise ValueError("pod_size must be >= 1")
    n_pods = -(-n_servers // pod_size)
    inc = (np.arange(n_servers, dtype=np.int32)
           // pod_size)[:, None].astype(np.int32)
    return Topology("partitioned", n_servers, n_pods, 1, inc)


def single_pool(n_servers: int) -> Topology:
    """The 1-pod degenerate: every server reaches pod 0.  Must price
    bitwise-identically to the single-pool engines at equal capacity
    (asserted in ``tests/test_topology_engine.py``)."""
    t = partitioned(n_servers, n_servers)
    return Topology("single", n_servers, 1, 1, t.inc)


def overlapping(n_servers: int, pod_size: int, fanout: int) -> Topology:
    """Cyclic Octopus-style overlap at the partitioned pod count.

    Server ``s`` reaches pods ``(s // pod_size + j) % n_pods`` for
    ``j in [0, fanout)`` — its home pod first, then the next pods
    around the ring — so every pod keeps ``pod_size`` primary members
    and the hardware cost matches :func:`partitioned` exactly; only
    the reachability differs.  ``fanout`` clips to ``n_pods``.
    """
    if pod_size < 1 or fanout < 1:
        raise ValueError("pod_size and fanout must be >= 1")
    n_pods = -(-n_servers // pod_size)
    fanout = min(fanout, n_pods)
    home = np.arange(n_servers, dtype=np.int64) // pod_size
    inc = ((home[:, None] + np.arange(fanout)[None, :]) % n_pods)
    return Topology("overlapping", n_servers, n_pods, fanout,
                    inc.astype(np.int32))


def sparse(n_servers: int, n_pods: int, fanout: int, seed: int = 0,
           allow_orphans: bool = False) -> Topology:
    """Seeded random sparse incidence: each server draws ``fanout``
    distinct pods uniformly (row order = preference order).

    With ``allow_orphans=True`` roughly 1 in 4 servers reaches NO pod
    (an all ``-1`` row) — the "VM reachable by no pod" degenerate:
    pool-bearing decisions on those servers can only take the
    all-local fallback.  A pod with zero members can occur at any seed.
    """
    if n_pods < 1 or fanout < 1:
        raise ValueError("n_pods and fanout must be >= 1")
    fanout = min(fanout, n_pods)
    rng = np.random.default_rng(seed)
    inc = np.full((n_servers, fanout), -1, np.int32)
    for s in range(n_servers):
        if allow_orphans and rng.random() < 0.25:
            continue
        inc[s] = rng.choice(n_pods, size=fanout, replace=False)
    return Topology("sparse", n_servers, n_pods, fanout, inc)


# -------------------------------------------------------------- capacities --
def split_pool(total_pool_gb: float, n_pods: int) -> np.ndarray:
    """Split a total pool budget into integral per-pod GBs.

    Floors the total, gives every pod ``total // n_pods`` and spreads
    the remainder one GB at a time over the first pods — so equal
    total hardware compares across topologies while every per-pod
    capacity stays an integral GB (the bit-exact integer sweeps'
    domain).
    """
    if n_pods < 1:
        raise ValueError("n_pods must be >= 1")
    total = int(np.floor(total_pool_gb))
    if total < 0:
        raise ValueError("total_pool_gb must be >= 0")
    base, rem = divmod(total, n_pods)
    caps = np.full(n_pods, base, np.int64)
    caps[:rem] += 1
    return caps.astype(float)


def pod_caps_matrix(pod_gb, topologies) -> np.ndarray:
    """Normalize per-candidate pod capacities to a dense ``(C, P_max)``
    float array over a list of per-lane topologies.

    ``pod_gb`` may be a scalar (every pod of every lane), a 1-D
    ``(C,)`` array (per-lane uniform pod capacity) or a sequence of C
    per-pod arrays (each of length ``topologies[i].n_pods``).  Columns
    past a lane's pod count fill with 0 and are inert: no incidence
    row ever points at them.
    """
    c = len(topologies)
    p_max = max((t.n_pods for t in topologies), default=1)
    out = np.zeros((c, p_max))
    if np.isscalar(pod_gb) or getattr(pod_gb, "ndim", None) == 0:
        for i, t in enumerate(topologies):
            out[i, :t.n_pods] = float(pod_gb)
        return out
    if isinstance(pod_gb, np.ndarray) and pod_gb.ndim == 1 \
            and len(pod_gb) == c:
        for i, t in enumerate(topologies):
            out[i, :t.n_pods] = pod_gb[i]
        return out
    if len(pod_gb) != c:
        raise ValueError(
            f"pod_gb rows {len(pod_gb)} != {c} candidate lanes")
    for i, (t, row) in enumerate(zip(topologies, pod_gb)):
        row = np.atleast_1d(np.asarray(row, float))
        if len(row) == 1:
            out[i, :t.n_pods] = row[0]
        elif len(row) == t.n_pods:
            out[i, :t.n_pods] = row
        else:
            raise ValueError(
                f"lane {i}: {len(row)} pod capacities for "
                f"{t.n_pods} pods")
    return out
