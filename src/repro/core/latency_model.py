"""CXL pool latency model (Pond §4.1, Figures 7 & 8) + TPU tier analogue.

Latency budget per §2/§4.1 and [63,69-72]:
  * NUMA-local DRAM read           ~78 ns   (Intel Skylake measurement)
  * CXL port round trip            ~25 ns   per direction-pair (Intel [63])
  * controller-side overhead       ~20 ns   (ASIC MC, matches the 70ns
                                             end-to-end claim for 1 EMC hop)
  * retimer                        ~10 ns   each direction (>500mm traces)
  * CXL switch                     ~70-100 ns (ports/arbitration/NOC)

Pool-size mapping (Figure 7): <=8 sockets connect directly to one EMC
(half-IOD); 16 sockets need retimers on some lanes; 32-64 sockets add a
switch + retimers.  Figure 8: the multi-headed EMC saves the switch for
small pools — 1/3 lower latency than switch-only designs.
"""
from __future__ import annotations

import dataclasses
import math

NUMA_LOCAL_NS = 78.0
CXL_PORT_NS = 25.0
EMC_CTRL_NS = 20.0
RETIMER_NS = 10.0          # per direction
SWITCH_NS = 85.0           # midpoint of 70-100


def pond_latency_ns(pool_sockets: int) -> float:
    """End-to-end read latency (ns) for Pond's EMC-first design (Fig 7)."""
    lat = NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS
    if pool_sockets > 8:
        lat += 2 * RETIMER_NS            # longer traces need retimers
    if pool_sockets > 16:
        lat += SWITCH_NS + 2 * RETIMER_NS  # switch hop + its traces
    if pool_sockets > 32:
        lat += 2 * RETIMER_NS            # second-level fan-out
    return lat


def switch_only_latency_ns(pool_sockets: int) -> float:
    """Strawman without the multi-headed EMC (Fig 8): every pool needs a
    switch hop."""
    lat = NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS + SWITCH_NS
    if pool_sockets > 8:
        lat += 2 * RETIMER_NS
    if pool_sockets > 16:
        lat += 2 * RETIMER_NS
    if pool_sockets > 32:
        lat += 2 * RETIMER_NS
    return lat


def added_latency_ns(pool_sockets: int) -> float:
    return pond_latency_ns(pool_sockets) - NUMA_LOCAL_NS


def latency_increase_pct(pool_sockets: int) -> float:
    """Relative to NUMA-local; the paper's 182%/222% emulation points
    correspond to ~143ns and ~173ns absolute (Intel testbed)."""
    return 100.0 * pond_latency_ns(pool_sockets) / NUMA_LOCAL_NS


# --------------------------------------------------------------- TPU tier --
@dataclasses.dataclass(frozen=True)
class MemoryTier:
    """One level of a memory hierarchy: latency, bandwidth, capacity."""
    name: str
    latency_us: float
    gbps: float = 13.0
    capacity_gb: float = math.inf


@dataclasses.dataclass(frozen=True)
class TierHierarchy:
    """Parameterized tier hierarchy (Aquifer-style generalization).

    ``tiers[0]`` is the local tier; every further tier is a pool level
    (CXL pool, far CXL+RDMA, ...) ordered near to far.  The slowdown
    model generalizes :meth:`TierModel.slowdown_factor`: a workload
    sending traffic fraction ``f_t`` to tier ``t`` sees

        slowdown = 1 + sum_t f_t * (r_eff_t - 1)

    with ``r_eff_t = h + (1 - h) * latency_t / latency_local`` — ``h``
    is the hit rate of a DRAM cache fronting the pool tiers (pooled-
    memory prefetching; ``h = 0`` recovers the raw latency ratio).  For
    two tiers and ``h = 0`` this is bit-identical to
    ``TierModel.slowdown_factor`` (the parity contract the grid engine
    tests against).
    """
    tiers: tuple[MemoryTier, ...]
    cache_hit_rate: float = 0.0

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError("TierHierarchy needs a local + >=1 pool tier")

    @classmethod
    def from_tier_model(cls, tm: "TierModel | None" = None,
                        cache_hit_rate: float = 0.0) -> "TierHierarchy":
        tm = tm if tm is not None else TierModel()
        return cls((MemoryTier("local", tm.hbm_latency_us, tm.hbm_gbps),
                    MemoryTier("cxl_pool", tm.pool_latency_us,
                               tm.pool_gbps)),
                   cache_hit_rate)

    @classmethod
    def three_tier(cls, far_latency_us: float = 5.0,
                   far_gbps: float = 6.0,
                   cxl_capacity_gb: float = math.inf,
                   far_capacity_gb: float = math.inf,
                   cache_hit_rate: float = 0.0) -> "TierHierarchy":
        """local / CXL pool / far (CXL+RDMA) — Aquifer-style far tier."""
        tm = TierModel()
        return cls((MemoryTier("local", tm.hbm_latency_us, tm.hbm_gbps),
                    MemoryTier("cxl_pool", tm.pool_latency_us,
                               tm.pool_gbps, cxl_capacity_gb),
                    MemoryTier("far_pool", far_latency_us, far_gbps,
                               far_capacity_gb)),
                   cache_hit_rate)

    @property
    def n_pool_tiers(self) -> int:
        return len(self.tiers) - 1

    def latency_ratio(self, i: int) -> float:
        return self.tiers[i].latency_us / self.tiers[0].latency_us

    def effective_ratio(self, i: int) -> float:
        """Latency ratio of tier ``i`` behind the DRAM cache front."""
        if i == 0:
            return 1.0
        h = self.cache_hit_rate
        return h + (1.0 - h) * self.latency_ratio(i)

    def slowdown_factor(self, pool_traffic_fracs) -> float:
        """``pool_traffic_fracs[t]`` = traffic fraction to tier ``t+1``.

        Accepts a scalar for 2-tier hierarchies (the TierModel-
        compatible signature).  Terms accumulate in tier order — the
        exact fold the grid engine replicates elementwise.
        """
        if not hasattr(pool_traffic_fracs, "__len__"):
            pool_traffic_fracs = (pool_traffic_fracs,)
        if len(pool_traffic_fracs) != self.n_pool_tiers:
            raise ValueError(
                f"expected {self.n_pool_tiers} pool-traffic fractions, "
                f"got {len(pool_traffic_fracs)}")
        s = 1.0
        for i, f in enumerate(pool_traffic_fracs, start=1):
            s += f * (self.effective_ratio(i) - 1.0)
        return s

    def spill_fractions(self, demand_gb: float):
        """Waterfall fill near-to-far: GB landing on each tier plus any
        unplaceable remainder (local fills first — the zNUMA bias)."""
        fills, rem = [], float(demand_gb)
        for t in self.tiers:
            take = min(rem, t.capacity_gb)
            fills.append(take)
            rem -= take
        return fills, rem

    def transfer_s(self, nbytes: float, i: int) -> float:
        t = self.tiers[i]
        return t.latency_us * 1e-6 + nbytes / (t.gbps * 1e9)


@dataclasses.dataclass(frozen=True)
class TierModel:
    """Pond-JAX tier cost model (DESIGN.md §2): chip HBM vs host pool."""
    hbm_gbps: float = 819.0
    pool_gbps: float = 13.0          # PCIe-class effective per chip
    hbm_latency_us: float = 0.5
    pool_latency_us: float = 2.0

    def transfer_s(self, nbytes: float, tier: str) -> float:
        bw = self.hbm_gbps if tier == "local" else self.pool_gbps
        lat = self.hbm_latency_us if tier == "local" else self.pool_latency_us
        return lat * 1e-6 + nbytes / (bw * 1e9)

    def slowdown_factor(self, pool_fraction_of_traffic: float) -> float:
        """Latency-ratio model for a workload sending a fraction of its
        memory traffic to the pool tier (used by Fig 16 analogue)."""
        r = self.pool_latency_us / self.hbm_latency_us
        return 1.0 + pool_fraction_of_traffic * (r - 1.0)


def migration_seconds(gb: float) -> float:
    """One-time mitigation copy: ~50 ms per GB of pool memory (§4.2)."""
    return 0.050 * gb
