"""CXL pool latency model (Pond §4.1, Figures 7 & 8) + TPU tier analogue.

Latency budget per §2/§4.1 and [63,69-72]:
  * NUMA-local DRAM read           ~78 ns   (Intel Skylake measurement)
  * CXL port round trip            ~25 ns   per direction-pair (Intel [63])
  * controller-side overhead       ~20 ns   (ASIC MC, matches the 70ns
                                             end-to-end claim for 1 EMC hop)
  * retimer                        ~10 ns   each direction (>500mm traces)
  * CXL switch                     ~70-100 ns (ports/arbitration/NOC)

Pool-size mapping (Figure 7): <=8 sockets connect directly to one EMC
(half-IOD); 16 sockets need retimers on some lanes; 32-64 sockets add a
switch + retimers.  Figure 8: the multi-headed EMC saves the switch for
small pools — 1/3 lower latency than switch-only designs.
"""
from __future__ import annotations

import dataclasses

NUMA_LOCAL_NS = 78.0
CXL_PORT_NS = 25.0
EMC_CTRL_NS = 20.0
RETIMER_NS = 10.0          # per direction
SWITCH_NS = 85.0           # midpoint of 70-100


def pond_latency_ns(pool_sockets: int) -> float:
    """End-to-end read latency (ns) for Pond's EMC-first design (Fig 7)."""
    lat = NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS
    if pool_sockets > 8:
        lat += 2 * RETIMER_NS            # longer traces need retimers
    if pool_sockets > 16:
        lat += SWITCH_NS + 2 * RETIMER_NS  # switch hop + its traces
    if pool_sockets > 32:
        lat += 2 * RETIMER_NS            # second-level fan-out
    return lat


def switch_only_latency_ns(pool_sockets: int) -> float:
    """Strawman without the multi-headed EMC (Fig 8): every pool needs a
    switch hop."""
    lat = NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS + SWITCH_NS
    if pool_sockets > 8:
        lat += 2 * RETIMER_NS
    if pool_sockets > 16:
        lat += 2 * RETIMER_NS
    if pool_sockets > 32:
        lat += 2 * RETIMER_NS
    return lat


def added_latency_ns(pool_sockets: int) -> float:
    return pond_latency_ns(pool_sockets) - NUMA_LOCAL_NS


def latency_increase_pct(pool_sockets: int) -> float:
    """Relative to NUMA-local; the paper's 182%/222% emulation points
    correspond to ~143ns and ~173ns absolute (Intel testbed)."""
    return 100.0 * pond_latency_ns(pool_sockets) / NUMA_LOCAL_NS


# --------------------------------------------------------------- TPU tier --
@dataclasses.dataclass(frozen=True)
class TierModel:
    """Pond-JAX tier cost model (DESIGN.md §2): chip HBM vs host pool."""
    hbm_gbps: float = 819.0
    pool_gbps: float = 13.0          # PCIe-class effective per chip
    hbm_latency_us: float = 0.5
    pool_latency_us: float = 2.0

    def transfer_s(self, nbytes: float, tier: str) -> float:
        bw = self.hbm_gbps if tier == "local" else self.pool_gbps
        lat = self.hbm_latency_us if tier == "local" else self.pool_latency_us
        return lat * 1e-6 + nbytes / (bw * 1e9)

    def slowdown_factor(self, pool_fraction_of_traffic: float) -> float:
        """Latency-ratio model for a workload sending a fraction of its
        memory traffic to the pool tier (used by Fig 16 analogue)."""
        r = self.pool_latency_us / self.hbm_latency_us
        return 1.0 + pool_fraction_of_traffic * (r - 1.0)


def migration_seconds(gb: float) -> float:
    """One-time mitigation copy: ~50 ms per GB of pool memory (§4.2)."""
    return 0.050 * gb
