"""Synthetic Azure-like VM traces, calibrated to Pond's published stats.

Calibration targets (asserted in benchmarks/tests):
  * untouched memory: ~50% of VMs touch less than 50% of their DRAM
    (§3.2 — p50 untouched = 50%), customer-correlated (Resource Central).
  * slowdown @182% latency (Fig 5): 26% of workloads <1%, 43% <5%,
    21% >25%;  @222%: 23% <1%, 37% <5%, 37% >25%; monotone between the two.
  * PMU/TMA counters correlated with slowdown but with deliberate
    counterexamples (Finding 4: >20% slowdown at 2% DRAM-bound).
  * VM shapes: 2-48 cores, 2-8 GB/core, lognormal lifetimes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

N_PMU_FEATURES = 32

# piecewise slowdown bands: (cum_prob, lo, hi)
_BANDS_182 = [(0.26, 0.0, 0.01), (0.43, 0.01, 0.05),
              (0.79, 0.05, 0.25), (1.0, 0.25, 0.50)]
_BANDS_222 = [(0.23, 0.0, 0.01), (0.37, 0.01, 0.05),
              (0.63, 0.05, 0.25), (1.0, 0.25, 0.60)]


def _piecewise(u: np.ndarray, bands) -> np.ndarray:
    out = np.zeros_like(u)
    prev = 0.0
    for cum, lo, hi in bands:
        m = (u >= prev) & (u < cum)
        out[m] = lo + (u[m] - prev) / max(cum - prev, 1e-9) * (hi - lo)
        prev = cum
    return out


@dataclasses.dataclass
class VM:
    vm_id: int
    customer: int
    vm_type: int
    location: int
    guest_os: int
    cores: int
    mem_gb: float
    arrival: float          # seconds
    lifetime: float         # seconds
    untouched: float        # fraction of mem_gb never touched
    slow182: float
    slow222: float
    pmu: np.ndarray         # (N_PMU_FEATURES,)

    @property
    def departure(self) -> float:
        return self.arrival + self.lifetime


class Population:
    """Customer/workload priors; VMs sample from their customer's profile."""

    def __init__(self, n_customers: int = 200, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.n_customers = n_customers
        # zipf-ish popularity (computed first: the latent intensity u is
        # stratified so the VM-weighted u distribution stays ~uniform and
        # the Fig-4/5 slowdown bands hold regardless of popularity skew)
        w = 1.0 / np.arange(1, n_customers + 1) ** 0.7
        self.cust_popularity = w / w.sum()
        perm = rng.permutation(n_customers)
        p_perm = self.cust_popularity[perm]
        bands = np.cumsum(p_perm) - p_perm / 2
        u = np.empty(n_customers)
        u[perm] = bands                     # band width == popularity
        self.cust_u = u
        self.cust_untouched = rng.beta(2.0, 2.0, n_customers)
        self.cust_type = rng.integers(0, 12, n_customers)
        self.cust_loc = rng.integers(0, 6, n_customers)
        self.cust_os = rng.integers(0, 4, n_customers)
        # staggered demand waves: each customer bursts at its own daily
        # phase (production traces: per-server peaks do NOT coincide — the
        # variance pooling absorbs; cf. Fig 2b workload change)
        self.cust_phase = rng.uniform(0, 86400, n_customers)
        self.cust_burstiness = rng.uniform(0.2, 0.9, n_customers)

    def _pmu(self, u: float, rng) -> np.ndarray:
        f = np.zeros(N_PMU_FEATURES, np.float32)
        # Finding 4: ~6% of workloads break the dram_bound correlation
        confuse = rng.random() < 0.06
        eff_u = rng.random() * 0.15 if confuse else u
        f[0] = np.clip(0.02 + 0.55 * eff_u ** 1.4
                       + rng.normal(0, 0.015), 0, 1)      # dram_bound
        # TMA "memory bound" also counts L1/store stalls that say nothing
        # about pool-latency sensitivity -> a noisier counter (Finding 5)
        f[1] = np.clip(f[0] + 0.06 + 0.25 * rng.random()
                       + abs(rng.normal(0, 0.05)), 0, 1)
        f[2] = np.clip(0.3 * eff_u + rng.normal(0, 0.05), 0, 1)   # l3
        f[3] = np.clip(2.6 - 2.0 * eff_u + rng.normal(0, 0.2), 0.1, 4)  # ipc
        f[4] = np.clip(0.5 * eff_u + rng.normal(0, 0.1), 0, 1)    # bw util
        f[5] = np.clip(rng.normal(0.2, 0.1), 0, 1)        # frontend bound
        f[6] = np.clip(rng.normal(0.1, 0.05), 0, 1)       # bad spec
        f[7:] = rng.random(N_PMU_FEATURES - 7)            # uninformative
        return f

    def sample_vms(self, n: int, horizon_s: float, seed: int = 1,
                   start_id: int = 0) -> list[VM]:
        rng = np.random.default_rng(seed)
        custs = rng.choice(self.n_customers, n, p=self.cust_popularity)
        base = rng.uniform(0, horizon_s, n)
        # concentrate each customer's arrivals near its daily phase
        tod = np.where(
            rng.random(n) < self.cust_burstiness[custs],
            (self.cust_phase[custs]
             + rng.normal(0, 3 * 3600, n)) % 86400,
            rng.uniform(0, 86400, n))
        arrivals = np.minimum(
            np.floor(base / 86400) * 86400 + tod, horizon_s - 1)
        order = np.argsort(arrivals)
        custs, arrivals = custs[order], arrivals[order]
        vms = []
        for i in range(n):
            c = int(custs[i])
            u = float(np.clip(self.cust_u[c]
                              + rng.normal(0, 0.02), 0, 0.999999))
            cores = int(rng.choice([2, 4, 8, 16, 32, 48],
                                   p=[.30, .25, .20, .15, .07, .03]))
            ratio = float(rng.choice([2.0, 4.0, 8.0], p=[.35, .45, .20]))
            untouched = float(np.clip(self.cust_untouched[c]
                                      + rng.normal(0, 0.10), 0, 1))
            life = float(np.clip(rng.lognormal(np.log(2 * 3600), 1.4),
                                 300, 30 * 86400))
            vms.append(VM(
                vm_id=start_id + i, customer=c,
                vm_type=int(self.cust_type[c]),
                location=int(self.cust_loc[c]),
                guest_os=int(self.cust_os[c]),
                cores=cores, mem_gb=cores * ratio,
                arrival=float(arrivals[i]), lifetime=life,
                untouched=untouched,
                slow182=float(_piecewise(np.array([u]), _BANDS_182)[0]),
                slow222=float(_piecewise(np.array([u]), _BANDS_222)[0]),
                pmu=self._pmu(u, rng)))
        return vms


# ------------------------------------------------- feature extraction ------
def pmu_matrix(vms) -> np.ndarray:
    return np.stack([vm.pmu for vm in vms])


def slowdowns(vms, latency: int = 182) -> np.ndarray:
    return np.array([vm.slow182 if latency == 182 else vm.slow222
                     for vm in vms])


def metadata_features(vms, history: dict | None = None) -> np.ndarray:
    """UM-model features: customer history percentiles (the paper's
    strongest feature) + VM metadata."""
    hist = history or {}
    rows = []
    for vm in vms:
        h = hist.get(vm.customer)
        if h is None or len(h) < 3:
            percs = [0.5, 0.5, 0.5, 0.5]        # no-history prior
        else:
            percs = list(np.percentile(h, [80, 90, 95, 99]))
        rows.append(percs + [vm.vm_type, vm.cores, vm.mem_gb,
                             vm.location, vm.guest_os])
    return np.asarray(rows, np.float32)


def build_history(vms) -> dict:
    """Past untouched-memory observations per customer (rolling week)."""
    hist: dict[int, list] = {}
    for vm in vms:
        hist.setdefault(vm.customer, []).append(vm.untouched)
    return {c: np.asarray(v) for c, v in hist.items()}
