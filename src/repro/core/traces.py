"""Synthetic Azure-like VM traces, calibrated to Pond's published stats,
plus ingestion of real VM trace files.

Calibration targets (asserted in benchmarks/tests):
  * untouched memory: ~50% of VMs touch less than 50% of their DRAM
    (§3.2 — p50 untouched = 50%), customer-correlated (Resource Central).
  * slowdown @182% latency (Fig 5): 26% of workloads <1%, 43% <5%,
    21% >25%;  @222%: 23% <1%, 37% <5%, 37% >25%; monotone between the two.
  * PMU/TMA counters correlated with slowdown but with deliberate
    counterexamples (Finding 4: >20% slowdown at 2% DRAM-bound).
  * VM shapes: 2-48 cores, 2-8 GB/core, lognormal lifetimes.

Real-trace ingestion (``load_trace_file``): external VM traces — e.g.
the Azure public VM traces — load into the same :class:`VM` record
format the synthetic sampler emits, so the replay engine, cluster
simulator and control plane run on them unchanged.  The replay only
needs ``(arrival, lifetime, cores, mem_gb)`` columns; workload fields
the file does not carry (untouched memory, slowdowns, PMU counters) are
synthesized from a :class:`Population` prior so policy code keeps
working.  A miniature fixture trace ships with the package
(``fixture_trace_path()``) for tests and quickstarts.
"""
from __future__ import annotations

import csv
import dataclasses
import gzip
import os
import time

import numpy as np

from repro.core import obs

N_PMU_FEATURES = 32

# piecewise slowdown bands: (cum_prob, lo, hi)
_BANDS_182 = [(0.26, 0.0, 0.01), (0.43, 0.01, 0.05),
              (0.79, 0.05, 0.25), (1.0, 0.25, 0.50)]
_BANDS_222 = [(0.23, 0.0, 0.01), (0.37, 0.01, 0.05),
              (0.63, 0.05, 0.25), (1.0, 0.25, 0.60)]


def _piecewise(u: np.ndarray, bands) -> np.ndarray:
    out = np.zeros_like(u)
    prev = 0.0
    for cum, lo, hi in bands:
        m = (u >= prev) & (u < cum)
        out[m] = lo + (u[m] - prev) / max(cum - prev, 1e-9) * (hi - lo)
        prev = cum
    return out


@dataclasses.dataclass
class VM:
    vm_id: int
    customer: int
    vm_type: int
    location: int
    guest_os: int
    cores: int
    mem_gb: float
    arrival: float          # seconds
    lifetime: float         # seconds
    untouched: float        # fraction of mem_gb never touched
    slow182: float
    slow222: float
    pmu: np.ndarray         # (N_PMU_FEATURES,)

    @property
    def departure(self) -> float:
        return self.arrival + self.lifetime


class Population:
    """Customer/workload priors; VMs sample from their customer's profile."""

    def __init__(self, n_customers: int = 200, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.n_customers = n_customers
        # zipf-ish popularity (computed first: the latent intensity u is
        # stratified so the VM-weighted u distribution stays ~uniform and
        # the Fig-4/5 slowdown bands hold regardless of popularity skew)
        w = 1.0 / np.arange(1, n_customers + 1) ** 0.7
        self.cust_popularity = w / w.sum()
        perm = rng.permutation(n_customers)
        p_perm = self.cust_popularity[perm]
        bands = np.cumsum(p_perm) - p_perm / 2
        u = np.empty(n_customers)
        u[perm] = bands                     # band width == popularity
        self.cust_u = u
        self.cust_untouched = rng.beta(2.0, 2.0, n_customers)
        self.cust_type = rng.integers(0, 12, n_customers)
        self.cust_loc = rng.integers(0, 6, n_customers)
        self.cust_os = rng.integers(0, 4, n_customers)
        # staggered demand waves: each customer bursts at its own daily
        # phase (production traces: per-server peaks do NOT coincide — the
        # variance pooling absorbs; cf. Fig 2b workload change)
        self.cust_phase = rng.uniform(0, 86400, n_customers)
        self.cust_burstiness = rng.uniform(0.2, 0.9, n_customers)

    def _pmu(self, u: float, rng) -> np.ndarray:
        f = np.zeros(N_PMU_FEATURES, np.float32)
        # Finding 4: ~6% of workloads break the dram_bound correlation
        confuse = rng.random() < 0.06
        eff_u = rng.random() * 0.15 if confuse else u
        f[0] = np.clip(0.02 + 0.55 * eff_u ** 1.4
                       + rng.normal(0, 0.015), 0, 1)      # dram_bound
        # TMA "memory bound" also counts L1/store stalls that say nothing
        # about pool-latency sensitivity -> a noisier counter (Finding 5)
        f[1] = np.clip(f[0] + 0.06 + 0.25 * rng.random()
                       + abs(rng.normal(0, 0.05)), 0, 1)
        f[2] = np.clip(0.3 * eff_u + rng.normal(0, 0.05), 0, 1)   # l3
        f[3] = np.clip(2.6 - 2.0 * eff_u + rng.normal(0, 0.2), 0.1, 4)  # ipc
        f[4] = np.clip(0.5 * eff_u + rng.normal(0, 0.1), 0, 1)    # bw util
        f[5] = np.clip(rng.normal(0.2, 0.1), 0, 1)        # frontend bound
        f[6] = np.clip(rng.normal(0.1, 0.05), 0, 1)       # bad spec
        f[7:] = rng.random(N_PMU_FEATURES - 7)            # uninformative
        return f

    def sample_vms(self, n: int, horizon_s: float, seed: int = 1,
                   start_id: int = 0) -> list[VM]:
        rng = np.random.default_rng(seed)
        custs = rng.choice(self.n_customers, n, p=self.cust_popularity)
        base = rng.uniform(0, horizon_s, n)
        # concentrate each customer's arrivals near its daily phase
        tod = np.where(
            rng.random(n) < self.cust_burstiness[custs],
            (self.cust_phase[custs]
             + rng.normal(0, 3 * 3600, n)) % 86400,
            rng.uniform(0, 86400, n))
        arrivals = np.minimum(
            np.floor(base / 86400) * 86400 + tod, horizon_s - 1)
        order = np.argsort(arrivals)
        custs, arrivals = custs[order], arrivals[order]
        vms = []
        for i in range(n):
            c = int(custs[i])
            u = float(np.clip(self.cust_u[c]
                              + rng.normal(0, 0.02), 0, 0.999999))
            cores = int(rng.choice([2, 4, 8, 16, 32, 48],
                                   p=[.30, .25, .20, .15, .07, .03]))
            ratio = float(rng.choice([2.0, 4.0, 8.0], p=[.35, .45, .20]))
            untouched = float(np.clip(self.cust_untouched[c]
                                      + rng.normal(0, 0.10), 0, 1))
            life = float(np.clip(rng.lognormal(np.log(2 * 3600), 1.4),
                                 300, 30 * 86400))
            vms.append(VM(
                vm_id=start_id + i, customer=c,
                vm_type=int(self.cust_type[c]),
                location=int(self.cust_loc[c]),
                guest_os=int(self.cust_os[c]),
                cores=cores, mem_gb=cores * ratio,
                arrival=float(arrivals[i]), lifetime=life,
                untouched=untouched,
                slow182=float(_piecewise(np.array([u]), _BANDS_182)[0]),
                slow222=float(_piecewise(np.array([u]), _BANDS_222)[0]),
                pmu=self._pmu(u, rng)))
        return vms


# ------------------------------------------------- feature extraction ------
@dataclasses.dataclass
class VMTable:
    """Struct-of-arrays view of a VM list (one array per field).

    The compiled policy engine (``core/policy_engine.py``) consumes
    traces in this form: batched predictor inference, history
    percentiles and QoS sampling all operate on whole columns instead
    of walking :class:`VM` records.  Column ``i`` of every array
    corresponds to ``vms[i]``.
    """
    vm_id: np.ndarray       # (N,) int64
    customer: np.ndarray    # (N,) int64
    vm_type: np.ndarray     # (N,) int64
    location: np.ndarray    # (N,) int64
    guest_os: np.ndarray    # (N,) int64
    cores: np.ndarray       # (N,) int64
    mem_gb: np.ndarray      # (N,) float64
    arrival: np.ndarray     # (N,) float64
    lifetime: np.ndarray    # (N,) float64
    untouched: np.ndarray   # (N,) float64
    slow182: np.ndarray     # (N,) float64
    slow222: np.ndarray     # (N,) float64
    pmu: np.ndarray         # (N, N_PMU_FEATURES) float32

    def __len__(self) -> int:
        return len(self.vm_id)


def vm_table(vms) -> VMTable:
    """Compile a VM list into a :class:`VMTable` (one pass, no copies of
    the PMU rows beyond the stacked matrix).

    Usage::

        table = traces.vm_table(vms)
        dec = policy_engine.policy_decisions_compiled(
            vms, "pond", control_plane=cp, table=table)
    """
    n = len(vms)

    def ints(attr):
        return np.fromiter((getattr(vm, attr) for vm in vms), np.int64, n)

    def floats(attr):
        return np.fromiter((getattr(vm, attr) for vm in vms), float, n)

    return VMTable(
        vm_id=ints("vm_id"), customer=ints("customer"),
        vm_type=ints("vm_type"), location=ints("location"),
        guest_os=ints("guest_os"), cores=ints("cores"),
        mem_gb=floats("mem_gb"), arrival=floats("arrival"),
        lifetime=floats("lifetime"), untouched=floats("untouched"),
        slow182=floats("slow182"), slow222=floats("slow222"),
        pmu=(np.stack([vm.pmu for vm in vms]) if n
             else np.empty((0, N_PMU_FEATURES), np.float32)))


def pmu_matrix(vms) -> np.ndarray:
    return np.stack([vm.pmu for vm in vms])


def slowdowns(vms, latency: int = 182) -> np.ndarray:
    return np.array([vm.slow182 if latency == 182 else vm.slow222
                     for vm in vms])


def metadata_features(vms, history: dict | None = None) -> np.ndarray:
    """UM-model features: customer history percentiles (the paper's
    strongest feature) + VM metadata."""
    hist = history or {}
    rows = []
    for vm in vms:
        h = hist.get(vm.customer)
        if h is None or len(h) < 3:
            percs = [0.5, 0.5, 0.5, 0.5]        # no-history prior
        else:
            percs = list(np.percentile(h, [80, 90, 95, 99]))
        rows.append(percs + [vm.vm_type, vm.cores, vm.mem_gb,
                             vm.location, vm.guest_os])
    return np.asarray(rows, np.float32)


def build_history(vms) -> dict:
    """Past untouched-memory observations per customer (rolling week)."""
    hist: dict[int, list] = {}
    for vm in vms:
        hist.setdefault(vm.customer, []).append(vm.untouched)
    return {c: np.asarray(v) for c, v in hist.items()}


# ------------------------------------------------- real-trace ingestion ----
class TraceSchemaError(ValueError):
    """A trace file failed schema validation (missing/bad columns, bad
    values).  Subclasses ValueError so callers can catch either."""


#: canonical columns the replay engine needs; a ``departure`` column may
#: substitute for ``lifetime`` (lifetime = departure - arrival)
TRACE_COLUMNS = ("arrival", "lifetime", "cores", "mem_gb")

#: lowercase header aliases -> canonical names (Azure public-trace
#: spellings included: vmcreated/vmdeleted timestamps, core/memory counts)
_COLUMN_ALIASES = {
    "arrival": "arrival", "start": "arrival", "starttime": "arrival",
    "created": "arrival", "vmcreated": "arrival", "start_time": "arrival",
    "lifetime": "lifetime", "duration": "lifetime", "life": "lifetime",
    "departure": "departure", "end": "departure", "endtime": "departure",
    "deleted": "departure", "vmdeleted": "departure",
    "end_time": "departure",
    "cores": "cores", "core_count": "cores", "vmcorecount": "cores",
    "vcpus": "cores", "vmcorecountbucket": "cores",
    "mem_gb": "mem_gb", "mem": "mem_gb", "memory": "mem_gb",
    "memory_gb": "mem_gb", "vmmemory": "mem_gb",
    "vmmemorybucket": "mem_gb",
    "customer": "customer", "customer_id": "customer",
    "subscriptionid": "customer", "tenant": "customer",
    "vm_id": "vm_id", "vmid": "vm_id",
    "untouched": "untouched", "untouched_frac": "untouched",
}


def fixture_trace_path() -> str:
    """Path of the bundled miniature trace (CSV, ~50 VMs over two days).

    Useful for tests and quickstarts::

        vms = traces.load_trace_file(traces.fixture_trace_path())
    """
    return os.path.join(os.path.dirname(__file__), "data",
                        "azure_mini.csv")


def _read_table(path: str) -> dict[str, list]:
    """Read a CSV (optionally .gz) or parquet file into {column: values}.

    Column names are lowercased/stripped and mapped through the alias
    table; unknown columns are kept under their lowercase name.
    """
    lower = path.lower()
    if lower.endswith((".parquet", ".pq")):
        try:
            import pyarrow.parquet as pq
        except Exception as e:                       # pragma: no cover
            raise TraceSchemaError(
                f"{path}: reading parquet traces requires pyarrow, which "
                f"is not installed ({e}); convert the trace to CSV or "
                f"install pyarrow") from e
        table = pq.read_table(path)
        raw = {name: col.to_pylist()
               for name, col in zip(table.column_names, table.columns)}
    elif lower.endswith((".csv", ".csv.gz")):
        opener = gzip.open if lower.endswith(".gz") else open
        with opener(path, "rt", newline="") as f:
            reader = csv.DictReader(f)
            if reader.fieldnames is None:
                raise TraceSchemaError(f"{path}: empty file (no header)")
            raw = {name: [] for name in reader.fieldnames}
            for row in reader:
                for name in raw:
                    raw[name].append(row[name])
    else:
        raise TraceSchemaError(
            f"{path}: unsupported trace format (expected .csv, .csv.gz, "
            f".parquet or .pq)")
    out: dict[str, list] = {}
    for name, vals in raw.items():
        key = name.strip().lower()
        out[_COLUMN_ALIASES.get(key, key)] = vals
    return out


def _numeric(cols: dict, name: str, path: str,
             row_offset: int = 0) -> np.ndarray:
    vals = cols[name]
    out = np.empty(len(vals))
    for i, v in enumerate(vals):
        try:
            out[i] = float(v)
        except (TypeError, ValueError):
            raise TraceSchemaError(
                f"{path}: row {row_offset + i + 1}: column {name!r}: "
                f"{v!r} is not numeric") from None
    if not np.isfinite(out).all():
        i = int(np.flatnonzero(~np.isfinite(out))[0])
        raise TraceSchemaError(
            f"{path}: row {row_offset + i + 1}: column {name!r}: "
            f"non-finite value")
    return out


def _require_schema(cols: dict, path: str) -> None:
    """Raise on missing required columns (shared by both readers)."""
    missing = [c for c in ("arrival", "cores", "mem_gb") if c not in cols]
    if "lifetime" not in cols and "departure" not in cols:
        missing.append("lifetime (or departure)")
    if missing:
        raise TraceSchemaError(
            f"{path}: missing required column(s) {missing}; found "
            f"{sorted(cols)} (accepted aliases: "
            f"{sorted(set(_COLUMN_ALIASES))})")


def _schema_arrays(cols: dict, path: str, row_offset: int = 0):
    """Validated (arrival, lifetime, cores, mem_gb) float arrays for a
    raw column dict, with the offending GLOBAL row in every error."""
    arrival = _numeric(cols, "arrival", path, row_offset)
    if "lifetime" in cols:
        lifetime = _numeric(cols, "lifetime", path, row_offset)
    else:
        lifetime = _numeric(cols, "departure", path, row_offset) - arrival
    cores = _numeric(cols, "cores", path, row_offset)
    mem = _numeric(cols, "mem_gb", path, row_offset)
    for name, arr, ok, req in (
            ("arrival", arrival, arrival >= 0.0, ">= 0"),
            ("lifetime", lifetime, lifetime > 0.0, "> 0"),
            ("cores", cores, cores >= 1.0, ">= 1"),
            ("mem_gb", mem, mem > 0.0, "> 0")):
        if not ok.all():
            i = int(np.flatnonzero(~ok)[0])
            raise TraceSchemaError(
                f"{path}: row {row_offset + i + 1}: column {name!r}: "
                f"{arr[i]:g} must be {req}")
    return arrival, lifetime, cores, mem


#: injectable sleep for the IO-retry backoff (tests monkeypatch this so
#: retry schedules are asserted without real waiting)
_sleep = time.sleep


@dataclasses.dataclass
class IngestReport:
    """Fault ledger of one chunked ingestion pass.

    Pass ``report=IngestReport(max_bad_rows=...)`` to
    :func:`iter_trace_chunks`: malformed rows (non-numeric/non-finite
    cells or domain violations in the four schema columns) are
    QUARANTINED — dropped with a record here — instead of aborting the
    stream, until the budget is exceeded, at which point ingestion
    raises :class:`TraceSchemaError` citing the budget.  Transient IO
    errors retried by the resilient reader increment ``io_retries``.
    ``benchmarks/azure_e2e.py`` surfaces :meth:`summary` in its run
    report.
    """

    max_bad_rows: int = 0
    bad_rows: list = dataclasses.field(default_factory=list)
    io_retries: int = 0

    @property
    def n_quarantined(self) -> int:
        return len(self.bad_rows)

    def add(self, path: str, row: int, column: str, value,
            reason: str) -> None:
        self.bad_rows.append({"row": row, "column": column,
                              "value": str(value)[:80],
                              "reason": reason})
        if self.n_quarantined > self.max_bad_rows:
            raise TraceSchemaError(
                f"{path}: too many malformed rows "
                f"({self.n_quarantined} > max_bad_rows="
                f"{self.max_bad_rows}); last: row {row} column "
                f"{column!r}: {value!r} {reason}")

    def summary(self) -> dict:
        """JSON-able digest (first 20 quarantine records)."""
        return {"n_quarantined": self.n_quarantined,
                "io_retries": self.io_retries,
                "bad_rows": self.bad_rows[:20]}


def _lenient_numeric(vals) -> tuple[np.ndarray, np.ndarray]:
    """Float array + bad mask (non-numeric/non-finite), never raising."""
    out = np.empty(len(vals))
    bad = np.zeros(len(vals), bool)
    for i, v in enumerate(vals):
        try:
            out[i] = float(v)
        except (TypeError, ValueError):
            out[i], bad[i] = np.nan, True
    bad |= ~np.isfinite(out)
    return out, bad


def _schema_arrays_quarantine(cols: dict, path: str, row_offset: int,
                              report: IngestReport):
    """Per-row masked pendant of :func:`_schema_arrays`: instead of
    aborting on the first malformed row, every offending row is
    recorded in ``report`` (which enforces its ``max_bad_rows`` budget)
    and masked out.  Returns the validated arrays pre-filtered to the
    kept rows plus the keep mask (for filtering the non-schema
    columns).  Each quarantined row records its FIRST offending column
    in schema order.
    """
    arrival, bad_arr = _lenient_numeric(cols["arrival"])
    if "lifetime" in cols:
        lifetime, bad_life = _lenient_numeric(cols["lifetime"])
        life_src = "lifetime"
    else:
        dep, bad_life = _lenient_numeric(cols["departure"])
        lifetime = dep - arrival
        bad_life |= bad_arr
        life_src = "departure"
    cores, bad_cores = _lenient_numeric(cols["cores"])
    mem, bad_mem = _lenient_numeric(cols["mem_gb"])
    rules = (("arrival", "arrival", bad_arr, arrival < 0, ">= 0"),
             ("lifetime", life_src, bad_life, ~(lifetime > 0), "> 0"),
             ("cores", "cores", bad_cores, ~(cores >= 1), ">= 1"),
             ("mem_gb", "mem_gb", bad_mem, ~(mem > 0), "> 0"))
    keep = np.ones(len(arrival), bool)
    for name, src, bad_num, bad_dom, req in rules:
        bad = (bad_num | bad_dom) & keep
        keep &= ~bad
        for i in np.flatnonzero(bad):
            i = int(i)
            report.add(path, row_offset + i + 1, name,
                       cols[src][i],
                       "is not a finite number" if bad_num[i]
                       else f"must be {req}")
    idx = np.flatnonzero(keep)
    return arrival[idx], lifetime[idx], cores[idx], mem[idx], keep


def _resilient_raw_chunks(path: str, chunk_vms: int, io_retries: int,
                          io_backoff_s: float,
                          report: IngestReport | None):
    """Retry wrapper over :func:`_iter_raw_chunks` for transient IO.

    On an ``OSError`` mid-stream the file is reopened, already-delivered
    chunks are skipped (chunk boundaries are deterministic in
    ``chunk_vms``), and reading resumes — with exponential backoff
    (``io_backoff_s * 2**attempt`` via the injectable :data:`_sleep`).
    ``io_retries`` bounds CONSECUTIVE failed attempts; any successfully
    delivered chunk resets the budget.  Schema errors are never
    retried — they are deterministic, not transient.
    """
    delivered = 0
    attempt = 0
    while True:
        try:
            to_skip = delivered      # frozen: delivered grows mid-loop
            skipped = 0
            for cols in _iter_raw_chunks(path, chunk_vms):
                if skipped < to_skip:
                    skipped += 1
                    continue
                yield cols
                delivered += 1
                attempt = 0
            return
        except TraceSchemaError:
            raise
        except OSError:
            attempt += 1
            if attempt > io_retries:
                raise
            if report is not None:
                report.io_retries += 1
            _sleep(io_backoff_s * 2 ** (attempt - 1))


def load_trace_file(path: str, max_vms: int | None = None,
                    start_id: int = 0, seed: int = 0,
                    population: "Population | None" = None) -> list[VM]:
    """Load an external VM trace file into ``sample_vms``-format records.

    Accepts CSV (optionally gzipped) or parquet with columns ``(arrival,
    lifetime, cores, mem_gb)`` — common spellings are aliased, e.g. the
    Azure public traces' ``vmcreated``/``vmdeleted`` (``lifetime`` is
    then ``departure - arrival``), ``vmcorecount`` and ``vmmemory``.
    Optional ``customer``, ``vm_id`` and ``untouched`` columns are used
    when present.  Workload fields a trace cannot carry (untouched
    memory without an ``untouched`` column, slowdowns, PMU counters) are
    synthesized deterministically (``seed``) from a
    :class:`Population` prior so the Pond control plane and predictors
    run on real traces unchanged; replay-engine results depend only on
    the four schema columns.

    Raises :class:`TraceSchemaError` (a ``ValueError``) on missing
    columns, non-numeric/non-finite cells, non-positive lifetimes,
    cores < 1, or mem_gb <= 0 — with the offending row in the message.

    Usage::

        vms = traces.load_trace_file("azure_2019.csv.gz", max_vms=50_000)
        eng = replay_engine.CompiledReplay(vms, decisions, cfg)
    """
    cols = _read_table(path)
    _require_schema(cols, path)
    n = len(cols["arrival"])
    if n == 0:
        raise TraceSchemaError(f"{path}: trace has no rows")

    arrival, lifetime, cores, mem = _schema_arrays(cols, path)

    pop = population or Population(n_customers=64, seed=seed)
    rng = np.random.default_rng(seed)
    if "customer" in cols:
        cust_raw = cols["customer"]
        cust_map: dict = {}
        custs = np.array([cust_map.setdefault(c, len(cust_map))
                          for c in cust_raw]) % pop.n_customers
    else:
        custs = rng.choice(pop.n_customers, n, p=pop.cust_popularity)
    untouched_col = (_numeric(cols, "untouched", path)
                     if "untouched" in cols else None)
    if "vm_id" in cols:
        try:
            vm_ids = [start_id + int(float(v)) for v in cols["vm_id"]]
        except (TypeError, ValueError):
            # opaque string ids (e.g. Azure vmid hashes): stable remap
            id_map: dict = {}
            vm_ids = [start_id + id_map.setdefault(v, len(id_map))
                      for v in cols["vm_id"]]
        seen: set = set()
        for i, v in enumerate(vm_ids):
            if v in seen:
                raise TraceSchemaError(
                    f"{path}: row {i + 1}: duplicate vm_id "
                    f"{cols['vm_id'][i]!r} — the replay keys placement "
                    f"by vm_id, so each VM needs one record")
            seen.add(v)
    else:
        vm_ids = [start_id + i for i in range(n)]

    # synthesized workload fields, vectorized over the whole trace
    u_all = np.clip(pop.cust_u[custs] + rng.normal(0, 0.02, n),
                    0, 0.999999)
    if untouched_col is not None:
        untouched_all = np.clip(untouched_col, 0.0, 1.0)
    else:
        untouched_all = np.clip(
            pop.cust_untouched[custs] + rng.normal(0, 0.10, n), 0, 1)
    slow182_all = _piecewise(u_all, _BANDS_182)
    slow222_all = _piecewise(u_all, _BANDS_222)

    order = np.argsort(arrival, kind="stable")
    if max_vms is not None:
        order = order[:max_vms]
    vms = []
    for i in order.tolist():
        c = int(custs[i])
        vms.append(VM(
            vm_id=vm_ids[i], customer=c,
            vm_type=int(pop.cust_type[c]),
            location=int(pop.cust_loc[c]),
            guest_os=int(pop.cust_os[c]),
            cores=int(round(cores[i])), mem_gb=float(mem[i]),
            arrival=float(arrival[i]), lifetime=float(lifetime[i]),
            untouched=float(untouched_all[i]),
            slow182=float(slow182_all[i]),
            slow222=float(slow222_all[i]),
            pmu=pop._pmu(float(u_all[i]), rng)))
    return vms


def _iter_raw_chunks(path: str, chunk_vms: int):
    """Yield raw alias-mapped column dicts of <= ``chunk_vms`` rows.

    Bounded-memory pendant of :func:`_read_table`: CSV (optionally .gz)
    rows stream through ``csv.DictReader``; parquet files read via
    ``pyarrow.ParquetFile.iter_batches`` so only one row-group batch is
    materialized at a time.
    """
    lower = path.lower()
    if lower.endswith((".parquet", ".pq")):
        try:
            import pyarrow.parquet as pq
        except Exception as e:                       # pragma: no cover
            raise TraceSchemaError(
                f"{path}: reading parquet traces requires pyarrow, which "
                f"is not installed ({e}); convert the trace to CSV or "
                f"install pyarrow") from e
        pf = pq.ParquetFile(path)
        for batch in pf.iter_batches(batch_size=chunk_vms):
            raw = {name: col.to_pylist()
                   for name, col in zip(batch.schema.names,
                                        batch.columns)}
            yield {_COLUMN_ALIASES.get(k.strip().lower(),
                                       k.strip().lower()): v
                   for k, v in raw.items()}
        return
    if not lower.endswith((".csv", ".csv.gz")):
        raise TraceSchemaError(
            f"{path}: unsupported trace format (expected .csv, .csv.gz, "
            f".parquet or .pq)")
    opener = gzip.open if lower.endswith(".gz") else open
    with opener(path, "rt", newline="") as f:
        reader = csv.DictReader(f)
        if reader.fieldnames is None:
            raise TraceSchemaError(f"{path}: empty file (no header)")
        # when two headers alias to one canonical column (e.g. the Azure
        # vmtable's vmcorecount + vmcorecountbucket) the LAST header
        # wins, exactly like _read_table's dict overwrite
        canon_src: dict[str, str] = {}
        for n in reader.fieldnames:
            canon_src[_COLUMN_ALIASES.get(n.strip().lower(),
                                          n.strip().lower())] = n
        names = [(orig, canon) for canon, orig in canon_src.items()]
        chunk = {canon: [] for _, canon in names}
        count = 0
        for row in reader:
            for name, canon in names:
                chunk[canon].append(row[name])
            count += 1
            if count == chunk_vms:
                yield chunk
                chunk = {canon: [] for _, canon in names}
                count = 0
        if count:
            yield chunk


def iter_trace_chunks(path: str, chunk_vms: int = 65536,
                      max_vms: int | None = None, start_id: int = 0,
                      seed: int = 0,
                      population: "Population | None" = None,
                      max_bad_rows: int = 0, io_retries: int = 0,
                      io_backoff_s: float = 0.5,
                      report: "IngestReport | None" = None):
    """Stream a trace file as bounded-memory chunks of ``VM`` records.

    Out-of-core pendant of :func:`load_trace_file` for traces that do
    not fit one in-memory table (e.g. the full Azure public packing
    trace, see ``scripts/fetch_azure_trace.py``): the file is read
    ``chunk_vms`` rows at a time through the same column-alias and
    schema-validation machinery, so errors still name the offending
    GLOBAL row.  Each yielded chunk is a ``load_trace_file``-format VM
    list sorted by arrival; customer and string-vm-id remaps are shared
    across chunks, so concatenating every chunk of an arrival-sorted
    file reproduces ``load_trace_file``'s ``(vm_id, arrival, lifetime,
    cores, mem_gb)`` columns exactly.  Synthesized workload fields
    (untouched/slowdowns/PMU without the optional columns) are
    deterministic in ``(seed, chunk_vms)`` but drawn from a different
    RNG stream than the monolithic loader — replay reject rates depend
    only on the four schema columns, so schema-only policies (local /
    static) price identically either way.

    Chunked ingestion requires arrivals to be non-decreasing ACROSS
    chunk boundaries (rows within a chunk may be unsorted); a violation
    raises :class:`TraceSchemaError` naming the row — sort the file or
    fall back to :func:`load_trace_file`.

    **Fault hardening** (all off by default — defaults are strict and
    bit-identical to the old behavior):

    * ``max_bad_rows > 0`` — malformed rows (non-numeric/non-finite
      cells, domain violations in the four schema columns) are
      QUARANTINED: dropped with a record in the :class:`IngestReport`
      instead of aborting a multi-hour ingest, until the budget is
      exceeded (then :class:`TraceSchemaError` cites the budget).
      Cross-chunk ordering violations and duplicate ``vm_id`` remain
      strict errors — they poison the replay, not just one row.  Under
      quarantine, row numbers in later per-chunk errors count kept
      rows.
    * ``io_retries > 0`` — transient ``OSError`` mid-stream (network
      filesystems, flaky disks) reopens the file and resumes after the
      already-delivered chunks, with exponential backoff
      (``io_backoff_s * 2**attempt``); the budget bounds consecutive
      failures and resets on every delivered chunk.
    * ``report=IngestReport(...)`` — pass your own ledger to read
      ``n_quarantined`` / ``io_retries`` / ``bad_rows`` afterwards
      (its ``max_bad_rows`` field then carries the budget); with
      ``max_bad_rows``/``io_retries`` args alone one is created
      internally.  ``benchmarks/azure_e2e.py`` surfaces the summary in
      its run report.

    When a recorder is live (``POND_TRACE=1`` or
    :func:`repro.core.obs.use_recorder`) each produced chunk is timed
    as an ``ingest.chunk`` span with ``ingest.rows`` / ``ingest.vms``
    counters, and the ledger's quarantine / IO-retry totals are folded
    into ``ingest.quarantined`` / ``ingest.io_retries`` when the
    stream closes.

    Usage (bounded-memory replay of an arbitrarily long trace)::

        report = traces.IngestReport(max_bad_rows=100)
        stream = replay_engine.CompiledReplayStream(
            traces.iter_trace_chunks("azure_packing.csv.gz",
                                     chunk_vms=100_000, io_retries=3,
                                     report=report),
            None, cfg, max_events_per_shard=250_000)
        rates = stream.reject_rates([300.0], [512.0])
        print(report.summary())
    """
    if report is None and (max_bad_rows > 0 or io_retries > 0):
        report = IngestReport(max_bad_rows=max_bad_rows)
    inner = _iter_trace_chunks_impl(path, chunk_vms, max_vms, start_id,
                                    seed, population, io_retries,
                                    io_backoff_s, report)
    rec = obs.get_recorder()
    if not rec.enabled:
        yield from inner
        return
    try:
        while True:
            with rec.span("ingest.chunk"):
                try:
                    vms = next(inner)
                except StopIteration:
                    break
            rec.count("ingest.chunks")
            rec.count("ingest.vms", len(vms))
            yield vms
    finally:
        if report is not None:
            rec.count("ingest.quarantined", report.n_quarantined)
            rec.count("ingest.io_retries", report.io_retries)


def _iter_trace_chunks_impl(path, chunk_vms, max_vms, start_id, seed,
                            population, io_retries, io_backoff_s,
                            report):
    """Chunk pipeline behind :func:`iter_trace_chunks` (``report``
    already resolved; the public wrapper adds the ingest spans and
    counters so consumer time is never charged to ingestion)."""
    rec = obs.get_recorder()
    pop = population or Population(n_customers=64, seed=seed)
    rng = np.random.default_rng(seed)
    cust_map: dict = {}
    id_map: dict = {}
    id_numeric: bool | None = None       # decided on first vm_id chunk
    seen_ids: set = set()
    prev_max = -np.inf
    row_offset = 0
    emitted = 0
    any_rows = False
    chunks = (_resilient_raw_chunks(path, chunk_vms, io_retries,
                                    io_backoff_s, report)
              if io_retries > 0 else _iter_raw_chunks(path, chunk_vms))
    for cols in chunks:
        _require_schema(cols, path)
        n_raw = n = len(cols["arrival"])
        if n == 0:
            continue
        any_rows = True
        if rec.enabled:
            rec.count("ingest.rows", n_raw)
        if report is not None:
            arrival, lifetime, cores, mem, keep = \
                _schema_arrays_quarantine(cols, path, row_offset,
                                          report)
            if not keep.all():
                idx = np.flatnonzero(keep).tolist()
                for key in ("customer", "vm_id", "untouched"):
                    if key in cols:
                        cols[key] = [cols[key][i] for i in idx]
                n = len(arrival)
                if n == 0:
                    row_offset += n_raw
                    continue
        else:
            arrival, lifetime, cores, mem = _schema_arrays(
                cols, path, row_offset)
        bad = arrival < prev_max
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise TraceSchemaError(
                f"{path}: row {row_offset + i + 1}: column 'arrival': "
                f"{arrival[i]:g} is earlier than a previous chunk's "
                f"latest arrival ({prev_max:g}); chunked ingestion needs "
                f"arrivals non-decreasing across chunk boundaries — sort "
                f"the trace by arrival (scripts/fetch_azure_trace.py "
                f"emits sorted files) or use load_trace_file")
        prev_max = max(prev_max, float(arrival.max()))

        if "customer" in cols:
            custs = np.array([cust_map.setdefault(c, len(cust_map))
                              for c in cols["customer"]]) % pop.n_customers
        else:
            custs = rng.choice(pop.n_customers, n, p=pop.cust_popularity)
        untouched_col = (np.clip(_numeric(cols, "untouched", path,
                                          row_offset), 0.0, 1.0)
                         if "untouched" in cols else None)
        if "vm_id" in cols:
            raw_ids = cols["vm_id"]
            if id_numeric is None:
                try:
                    [float(v) for v in raw_ids]
                    id_numeric = True
                except (TypeError, ValueError):
                    id_numeric = False
            if id_numeric:
                try:
                    vm_ids = [start_id + int(float(v)) for v in raw_ids]
                except (TypeError, ValueError) as e:
                    raise TraceSchemaError(
                        f"{path}: non-numeric vm_id after a numeric "
                        f"first chunk ({e}); chunked ingestion cannot "
                        f"remap ids retroactively — use load_trace_file") \
                        from None
            else:
                vm_ids = [start_id + id_map.setdefault(v, len(id_map))
                          for v in raw_ids]
            for i, v in enumerate(vm_ids):
                if v in seen_ids:
                    raise TraceSchemaError(
                        f"{path}: row {row_offset + i + 1}: duplicate "
                        f"vm_id {raw_ids[i]!r} — the replay keys "
                        f"placement by vm_id, so each VM needs one "
                        f"record")
                seen_ids.add(v)
        else:
            vm_ids = [start_id + row_offset + i for i in range(n)]

        u_all = np.clip(pop.cust_u[custs] + rng.normal(0, 0.02, n),
                        0, 0.999999)
        if untouched_col is not None:
            untouched_all = untouched_col
        else:
            untouched_all = np.clip(
                pop.cust_untouched[custs] + rng.normal(0, 0.10, n), 0, 1)
        slow182_all = _piecewise(u_all, _BANDS_182)
        slow222_all = _piecewise(u_all, _BANDS_222)

        order = np.argsort(arrival, kind="stable")
        if max_vms is not None:
            order = order[:max_vms - emitted]
        vms = []
        for i in order.tolist():
            c = int(custs[i])
            vms.append(VM(
                vm_id=vm_ids[i], customer=c,
                vm_type=int(pop.cust_type[c]),
                location=int(pop.cust_loc[c]),
                guest_os=int(pop.cust_os[c]),
                cores=int(round(cores[i])), mem_gb=float(mem[i]),
                arrival=float(arrival[i]), lifetime=float(lifetime[i]),
                untouched=float(untouched_all[i]),
                slow182=float(slow182_all[i]),
                slow222=float(slow222_all[i]),
                pmu=pop._pmu(float(u_all[i]), rng)))
        row_offset += n_raw
        emitted += len(vms)
        if vms:
            yield vms
        if max_vms is not None and emitted >= max_vms:
            return
    if not any_rows:
        raise TraceSchemaError(f"{path}: trace has no rows")


def save_trace_csv(vms, path: str) -> None:
    """Write VMs as a CSV (gzipped when ``path`` ends in .gz) the
    :func:`load_trace_file` schema round-trips (arrival, lifetime,
    cores, mem_gb + customer/vm_id/untouched)."""
    opener = gzip.open if path.lower().endswith(".gz") else open
    with opener(path, "wt", newline="") as f:
        w = csv.writer(f)
        w.writerow(["vm_id", "customer", "arrival", "lifetime", "cores",
                    "mem_gb", "untouched"])
        for vm in vms:
            w.writerow([vm.vm_id, vm.customer, f"{vm.arrival:.3f}",
                        f"{vm.lifetime:.3f}", vm.cores,
                        f"{vm.mem_gb:g}", f"{vm.untouched:.4f}"])
