"""Batched latency / QoS / zNUMA grid engine (Pond §4-§6 figure family).

The last scalar figure family — slowdown sensitivity (Fig 4), the CXL
latency model (Fig 7/8), zNUMA spill (Fig 15/16), the UM calibration
curve (Fig 18) and the Eq.(1) combined frontier (Fig 20) — rebuilt on
the grid machinery: every predicate evaluates over a (workload x
config) grid in one batched (and, for the event-driven spill sweep,
jitted ``lax.scan``) pass, **bit-exact** against the scalar seed code
kept as oracles:

* :func:`pond_latency_ns_grid` (+ switch-only / added / pct variants)
  == ``latency_model.pond_latency_ns`` looped — identical float-add
  order per element.
* :func:`slowdown_band_grid` == ``(s < t).mean()`` loops — bands count
  in integers, fractions divide on the host in float64 (numpy's bool
  mean is exactly count/size in float64).
* :func:`spill_grid` == replaying each ``(num_local, num_pool)`` config
  on ``znuma.ZNumaAllocator`` (:func:`scalar_spill_replay`): a
  ``lax.scan`` over alloc/free events carries per-lane free counters
  plus a (block x lane) tier map — integer state only, so the jax and
  numpy backends agree bitwise.  Config lanes pad to the sweep-core
  buckets (padding replicates the last config; results are sliced off).
* :func:`hierarchy_slowdown_grid` == ``TierHierarchy.slowdown_factor``
  looped (terms fold in tier order, matching the scalar accumulation) —
  and, through ``TierHierarchy.from_tier_model``, bit-identical to the
  two-tier ``TierModel.slowdown_factor``.
* :func:`li_curve_grid` / :func:`um_curve_grid` /
  :func:`combine_grid` == ``LatencySensitivityModel.curve`` /
  the Fig-18 tau loop / ``eqn1.combine`` — the combine grid flattens
  li-major so ``argmax`` reproduces the nested loop's first-strict-max
  tie-break.
* :func:`qos_mitigation_grid` / :func:`pdm_violation_grid` ==
  ``qos.QoSMonitor.check`` walks / the inclusive ``qos.exceeds_pdm``
  predicate over a PDM grid.

Every entry point takes ``backend="auto"|"jax"|"numpy"`` — "auto"
prefers jax when importable; both backends are parity-tested
(tests/test_latency_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import eqn1, qos, sweep_core
from repro.core.latency_model import (CXL_PORT_NS, EMC_CTRL_NS,
                                      NUMA_LOCAL_NS, RETIMER_NS, SWITCH_NS,
                                      TierHierarchy)
from repro.core.znuma import ZNumaAllocator

# spill-event kinds (pad events are no-ops on every lane)
ALLOC, FREE, PAD = 0, 1, 2


def _use_jax(backend: str) -> bool:
    if backend == "numpy":
        return False
    if backend == "jax":
        if not sweep_core.jax_importable():
            raise RuntimeError("jax backend requested but not importable")
        return True
    return sweep_core.jax_importable()


def _jnp_x64():
    """jax.numpy + the enable-x64 context: the float grids compare and
    accumulate in float64, matching the numpy oracles bitwise (jax
    defaults to float32 otherwise)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    return jnp, enable_x64


# ------------------------------------------------- Fig 7/8 latency model --
def pond_latency_ns_grid(pool_sockets) -> np.ndarray:
    """Vectorized ``pond_latency_ns`` — identical add order per element."""
    s = np.asarray(pool_sockets)
    lat = np.full(s.shape, NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS)
    lat = np.where(s > 8, lat + 2 * RETIMER_NS, lat)
    lat = np.where(s > 16, lat + (SWITCH_NS + 2 * RETIMER_NS), lat)
    lat = np.where(s > 32, lat + 2 * RETIMER_NS, lat)
    return lat


def switch_only_latency_ns_grid(pool_sockets) -> np.ndarray:
    s = np.asarray(pool_sockets)
    lat = np.full(s.shape, NUMA_LOCAL_NS + 2 * CXL_PORT_NS + EMC_CTRL_NS
                  + SWITCH_NS)
    for edge in (8, 16, 32):
        lat = np.where(s > edge, lat + 2 * RETIMER_NS, lat)
    return lat


def added_latency_ns_grid(pool_sockets) -> np.ndarray:
    return pond_latency_ns_grid(pool_sockets) - NUMA_LOCAL_NS


def latency_increase_pct_grid(pool_sockets) -> np.ndarray:
    return 100.0 * pond_latency_ns_grid(pool_sockets) / NUMA_LOCAL_NS


# -------------------------------------------------- Fig 4 slowdown bands --
def slowdown_band_grid(slow, lt=(0.01, 0.05), gt=(0.25,),
                       backend: str = "auto") -> np.ndarray:
    """Band fractions over a slowdown grid.

    ``slow``: (..., N) per-workload slowdowns (any number of leading
    batch axes: seeds, latencies, ...).  Returns (..., len(lt)+len(gt))
    float64 fractions — ``out[..., i] = (slow < lt[i]).mean(-1)`` then
    ``(slow > gt[j]).mean(-1)``, bit-exact vs the scalar means because
    the counts are integers and the division is a single float64 op.
    """
    slow = np.asarray(slow, np.float64)
    n = slow.shape[-1]
    lt_a = np.asarray(lt, np.float64)
    gt_a = np.asarray(gt, np.float64)
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            lo = jnp.sum(jnp.asarray(slow)[..., None, :]
                         < jnp.asarray(lt_a)[:, None], axis=-1)
            hi = jnp.sum(jnp.asarray(slow)[..., None, :]
                         > jnp.asarray(gt_a)[:, None], axis=-1)
            counts = np.concatenate([np.asarray(lo), np.asarray(hi)],
                                    axis=-1)
    else:
        lo = (slow[..., None, :] < lt_a[:, None]).sum(-1)
        hi = (slow[..., None, :] > gt_a[:, None]).sum(-1)
        counts = np.concatenate([lo, hi], axis=-1)
    return counts.astype(np.float64) / n


# --------------------------------------------- tier-hierarchy slowdowns --
def hierarchy_params(hierarchies) -> tuple[np.ndarray, np.ndarray]:
    """Stack (C,) hierarchies (equal depth) into ``(ratios, hits)``
    arrays for :func:`hierarchy_slowdown_grid`."""
    depths = {h.n_pool_tiers for h in hierarchies}
    if len(depths) != 1:
        raise ValueError(f"mixed hierarchy depths {sorted(depths)}")
    ratios = np.array([[h.latency_ratio(i + 1)
                        for i in range(h.n_pool_tiers)]
                       for h in hierarchies], np.float64)
    hits = np.array([h.cache_hit_rate for h in hierarchies], np.float64)
    return ratios, hits


def hierarchy_slowdown_grid(fracs, ratios, hits,
                            backend: str = "auto") -> np.ndarray:
    """Slowdown factors over a (workload x hierarchy-config) grid.

    ``fracs``: (..., T) per-pool-tier traffic fractions; ``ratios``:
    (C, T) tier latency ratios; ``hits``: (C,) DRAM-cache hit rates.
    Returns (..., C) slowdown factors.  The per-tier terms accumulate
    in tier order starting from 1.0 — the exact fold of the scalar
    ``TierHierarchy.slowdown_factor`` — so every element is bitwise the
    scalar result.
    """
    fracs = np.asarray(fracs, np.float64)
    ratios = np.asarray(ratios, np.float64)
    hits = np.asarray(hits, np.float64)
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            eff = hits[:, None] \
                + (1.0 - hits[:, None]) * jnp.asarray(ratios)
            terms = jnp.asarray(fracs)[..., None, :] * (eff - 1.0)
            out = jnp.ones(terms.shape[:-1])
            for t in range(terms.shape[-1]):
                out = out + terms[..., t]
            return np.asarray(out)
    eff = hits[:, None] + (1.0 - hits[:, None]) * ratios
    terms = fracs[..., None, :] * (eff - 1.0)
    out = np.ones(terms.shape[:-1])
    for t in range(terms.shape[-1]):
        out = out + terms[..., t]
    return out


def pdm_violation_grid(slowdown_frac, pdm_grid,
                       backend: str = "auto") -> np.ndarray:
    """Fraction of workloads at-or-beyond each PDM (inclusive predicate
    ``qos.exceeds_pdm``).  ``slowdown_frac``: (..., N) relative
    slowdowns; ``pdm_grid``: (P,).  Returns (..., P) float64."""
    s = np.asarray(slowdown_frac, np.float64)
    p = np.asarray(pdm_grid, np.float64)
    n = s.shape[-1]
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            counts = np.asarray(jnp.sum(
                jnp.asarray(s)[..., None, :] >= jnp.asarray(p)[:, None],
                axis=-1))
    else:
        counts = qos.exceeds_pdm(s[..., None, :], p[:, None]).sum(-1)
    return counts.astype(np.float64) / n


# ------------------------------------------------------ Fig 15/16 spill --
@dataclasses.dataclass
class SpillGrid:
    """Per-config zNUMA accounting (trailing axis = config lane)."""
    allocs: np.ndarray          # successful allocations
    pool_allocs: np.ndarray
    failed: np.ndarray          # MemoryError allocations (both tiers full)
    local_in_use: np.ndarray
    pool_in_use: np.ndarray

    @property
    def spill_fraction(self) -> np.ndarray:
        a = self.allocs.astype(np.float64)
        return np.where(self.allocs > 0,
                        self.pool_allocs.astype(np.float64)
                        / np.where(self.allocs > 0, a, 1.0), 0.0)


def compile_block_events(events) -> tuple[np.ndarray, np.ndarray]:
    """Compile ``[("alloc"|"free", block_key), ...]`` into int32 event
    arrays (kinds, keys).  Block keys are dense logical ids."""
    kind_of = {"alloc": ALLOC, "free": FREE}
    kinds = np.fromiter((kind_of[k] for k, _ in events), np.int32,
                        len(events))
    keys = np.fromiter((b for _, b in events), np.int32, len(events))
    return kinds, keys


def scalar_spill_replay(ev_kind, ev_key, num_local: int,
                        num_pool: int) -> SpillGrid:
    """Oracle: replay one config on ``znuma.ZNumaAllocator``.

    Failed allocations leave the key unbound; freeing an unbound key is
    a no-op (mirrors the engine's tier map)."""
    alloc = ZNumaAllocator(int(num_local), int(num_pool))
    held: dict[int, int] = {}
    failed = 0
    for kind, key in zip(ev_kind, ev_key):
        if kind == ALLOC:
            try:
                held[int(key)] = alloc.alloc()
            except MemoryError:
                failed += 1
        elif kind == FREE:
            blk = held.pop(int(key), None)
            if blk is not None:
                alloc.free(blk)
    mk = lambda v: np.asarray(v, np.int64)
    return SpillGrid(mk(alloc.allocs), mk(alloc.pool_allocs), mk(failed),
                     mk(alloc.local_in_use), mk(alloc.pool_in_use))


@functools.lru_cache(maxsize=None)
def _build_spill_sweep(batched: bool):
    import jax
    import jax.numpy as jnp
    from jax import lax

    def body(carry, ev):
        free_l, free_p, tier, allocs, pool_allocs, failed = carry
        kind, key = ev[0], ev[1]
        is_alloc = kind == ALLOC
        is_free = kind == FREE
        take_l = is_alloc & (free_l > 0)
        take_p = is_alloc & (free_l <= 0) & (free_p > 0)
        fail = is_alloc & (free_l <= 0) & (free_p <= 0)
        row = lax.dynamic_index_in_dim(tier, key, 0, keepdims=False)
        freed_l = is_free & (row == 0)
        freed_p = is_free & (row == 1)
        free_l = free_l - take_l + freed_l
        free_p = free_p - take_p + freed_p
        new_row = jnp.where(take_l, 0,
                            jnp.where(take_p, 1,
                                      jnp.where(is_free, -1, row)))
        tier = lax.dynamic_update_index_in_dim(
            tier, new_row.astype(tier.dtype), key, 0)
        allocs = allocs + (take_l | take_p)
        pool_allocs = pool_allocs + take_p
        failed = failed + fail
        return (free_l, free_p, tier, allocs, pool_allocs, failed), None

    def sweep(ev, num_local, num_pool, tier0):
        zeros = jnp.zeros_like(num_local)
        carry0 = (num_local, num_pool, tier0, zeros, zeros, zeros)
        carry, _ = lax.scan(body, carry0, ev)
        free_l, free_p, _, allocs, pool_allocs, failed = carry
        return (allocs, pool_allocs, failed,
                num_local - free_l, num_pool - free_p)

    if batched:
        sweep = jax.vmap(sweep, in_axes=(0, None, None, None))
    return jax.jit(sweep)


def _numpy_spill_sweep(ev, num_local, num_pool, n_keys: int):
    free_l = num_local.copy()
    free_p = num_pool.copy()
    tier = np.full((n_keys, len(num_local)), -1, np.int32)
    allocs = np.zeros_like(free_l)
    pool_allocs = np.zeros_like(free_l)
    failed = np.zeros_like(free_l)
    for kind, key in ev:
        if kind == ALLOC:
            take_l = free_l > 0
            take_p = ~take_l & (free_p > 0)
            fail = ~take_l & ~take_p
            free_l -= take_l
            free_p -= take_p
            tier[key] = np.where(take_l, 0, np.where(take_p, 1, tier[key]))
            allocs += take_l | take_p
            pool_allocs += take_p
            failed += fail
        elif kind == FREE:
            row = tier[key]
            free_l += row == 0
            free_p += row == 1
            tier[key] = -1
    return allocs, pool_allocs, failed, num_local - free_l, \
        num_pool - free_p


def spill_grid(ev_kind, ev_key, num_local, num_pool,
               backend: str = "auto") -> SpillGrid:
    """zNUMA spill accounting over a config grid, one scan pass.

    ``ev_kind``/``ev_key``: (E,) or (K, E) int event streams (kind
    :data:`PAD` is a no-op — the padding value for ragged batches);
    ``num_local``/``num_pool``: (C,) per-config tier sizes.  Returns a
    :class:`SpillGrid` with (C,) — or (K, C) — int64 counters, bitwise
    equal to :func:`scalar_spill_replay` per (stream, lane).

    Config lanes pad to the sweep-core bucket widths (padding
    replicates the last config; its lanes are sliced off), so XLA
    recompiles stay rare across grid shapes.
    """
    ev_kind = np.asarray(ev_kind, np.int32)
    ev_key = np.asarray(ev_key, np.int32)
    num_local = np.atleast_1d(np.asarray(num_local, np.int32))
    num_pool = np.atleast_1d(np.asarray(num_pool, np.int32))
    if num_local.shape != num_pool.shape:
        raise ValueError("num_local / num_pool shape mismatch")
    batched = ev_kind.ndim == 2
    c = len(num_local)
    width = sweep_core.bucket_width(c)
    nl = np.concatenate([num_local,
                         np.full(width - c, num_local[-1], np.int32)])
    npl = np.concatenate([num_pool,
                          np.full(width - c, num_pool[-1], np.int32)])
    n_keys = sweep_core.pad_up(int(ev_key.max(initial=0)) + 1, 32)
    ev = np.stack([ev_kind, ev_key], axis=-1)
    if _use_jax(backend):
        sweep = _build_spill_sweep(batched)
        tier0 = np.full((n_keys, width), -1, np.int32)
        out = sweep(sweep_core.device_put(ev),
                    sweep_core.device_put(nl),
                    sweep_core.device_put(npl),
                    sweep_core.device_put(tier0))
        arrs = [np.asarray(a)[..., :c].astype(np.int64) for a in out]
    elif batched:
        rows = [_numpy_spill_sweep(e, nl, npl, n_keys) for e in ev]
        arrs = [np.stack([r[i] for r in rows])[..., :c].astype(np.int64)
                for i in range(5)]
    else:
        out = _numpy_spill_sweep(ev, nl, npl, n_keys)
        arrs = [a[:c].astype(np.int64) for a in out]
    return SpillGrid(*arrs)


# --------------------------------------------------- Fig 17/18 LI + UM --
def default_li_thresholds() -> np.ndarray:
    return np.unique(np.round(np.linspace(0.0, 1.0, 101), 3))


def li_curve_grid(p, sens, thresholds=None,
                  backend: str = "auto") -> tuple[np.ndarray, np.ndarray,
                                                  np.ndarray]:
    """(LI, FP) fractions over a threshold grid in one pass.

    ``p``: (N,) sensitivity probabilities; ``sens``: (N,) bool truth
    (``qos.exceeds_pdm(slowdowns, pdm)``).  Returns ``(thresholds,
    li_frac, fp_frac)`` float64 — bit-exact vs
    ``LatencySensitivityModel.curve`` because ``li.mean()`` of a bool
    array is exactly count/size in float64.
    """
    p = np.asarray(p, np.float64)
    sens = np.asarray(sens, bool)
    ths = np.asarray(default_li_thresholds() if thresholds is None
                     else thresholds, np.float64)
    n = len(p)
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            li = jnp.asarray(p)[None, :] \
                < jnp.asarray(ths)[:, None]             # (T, N)
            li_c = np.asarray(jnp.sum(li, axis=1))
            fp_c = np.asarray(jnp.sum(
                li & jnp.asarray(sens)[None, :], axis=1))
    else:
        # sorted counts: #{p < t} and #{p_sens < t} via searchsorted
        li_c = np.searchsorted(np.sort(p), ths, side="left")
        fp_c = np.searchsorted(np.sort(p[sens]), ths, side="left")
    return ths, li_c.astype(np.float64) / n, fp_c.astype(np.float64) / n


def um_curve_grid(preds, actual) -> tuple[np.ndarray, np.ndarray]:
    """(UM, OP) per prediction row.  ``preds``: (T, N) per-tau
    predictions; ``actual``: (N,).  UM uses the same per-row float64
    ``mean`` reduction as the scalar loop; OP counts
    ``actual < pred`` in integers."""
    preds = np.asarray(preds, np.float64)
    actual = np.asarray(actual, np.float64)
    um = np.array([row.mean() for row in preds])
    op = (actual[None, :] < preds).sum(1).astype(np.float64) \
        / preds.shape[1]
    return um, op


# ------------------------------------------------- Fig 20 combine grid --
def combine_grid(li_curve, um_curve, budgets, spill_harm_prob: float = 0.25,
                 backend: str = "auto") -> list:
    """Vectorized ``eqn1.combine`` over a budget grid.

    The (L, U) candidate matrices flatten li-major so the first-
    occurrence ``argmax`` reproduces the nested loop's strict-``>``
    first-max tie-break; invalid cells mask to -inf.  Returns one
    ``eqn1.CombinedOperatingPoint`` per budget, each bitwise equal to
    the scalar ``eqn1.combine``.
    """
    li = np.asarray([c[0] for c in li_curve], np.float64)
    fp = np.asarray([c[1] for c in li_curve], np.float64)
    um = np.asarray([c[0] for c in um_curve], np.float64)
    op = np.asarray([c[1] for c in um_curve], np.float64)
    pf = li[:, None] + (1.0 - li[:, None]) * um[None, :]
    mis = fp[:, None] + op[None, :] * spill_harm_prob
    budgets = np.atleast_1d(np.asarray(budgets, np.float64))
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            ok = (jnp.asarray(fp)[None, :, None]
                  <= jnp.asarray(budgets)[:, None, None]) \
                & (jnp.asarray(mis)[None]
                   <= jnp.asarray(budgets)[:, None, None])
            cand = jnp.where(ok, jnp.asarray(pf)[None], -jnp.inf)
            flat = cand.reshape(len(budgets), -1)
            idx = np.asarray(jnp.argmax(flat, axis=1))
            best = np.asarray(jnp.max(flat, axis=1))
    else:
        ok = (fp[None, :, None] <= budgets[:, None, None]) \
            & (mis[None] <= budgets[:, None, None])
        cand = np.where(ok, pf[None], -np.inf)
        flat = cand.reshape(len(budgets), -1)
        idx = np.argmax(flat, axis=1)
        best = flat[np.arange(len(budgets)), idx]
    out = []
    n_um = len(um)
    for b in range(len(budgets)):
        if not best[b] > 0.0:               # no candidate beat the zero pt
            out.append(eqn1.CombinedOperatingPoint(0, 0, 0, 0, 0, 0))
            continue
        i, j = divmod(int(idx[b]), n_um)
        out.append(eqn1.CombinedOperatingPoint(
            float(fp[i]), float(op[j]), float(li[i]), float(um[j]),
            float(pf[i, j]), float(mis[i, j])))
    return out


# ----------------------------------------------------------- QoS grids --
def qos_mitigation_grid(p, spilled, pool_gb, thresholds, migrated=None,
                        backend: str = "auto") -> tuple[np.ndarray,
                                                        np.ndarray]:
    """The QoS monitor's mitigation predicate over a threshold grid.

    ``p``: (N,) predicted sensitivity; ``spilled``: (N,) bool;
    ``pool_gb``: (N,); ``thresholds``: (C,); ``migrated``: optional
    (N,) bool of already-migrated VMs.  Returns ``(mitigate (C, N)
    bool, n_mitigations (C,))`` — row c bitwise equals walking
    ``qos.QoSMonitor.check`` over the N VMs at threshold c.
    """
    p = np.asarray(p, np.float64)
    spilled = np.asarray(spilled, bool)
    pool_gb = np.asarray(pool_gb, np.float64)
    ths = np.atleast_1d(np.asarray(thresholds, np.float64))
    prev = np.zeros(len(p), bool) if migrated is None \
        else np.asarray(migrated, bool)
    if _use_jax(backend):
        jnp, enable_x64 = _jnp_x64()
        with enable_x64():
            mit = (~jnp.asarray(prev) & jnp.asarray(spilled)
                   & (jnp.asarray(pool_gb) > 0))[None, :] \
                & (jnp.asarray(p)[None, :] >= jnp.asarray(ths)[:, None])
            mit = np.asarray(mit)
    else:
        mit = (~prev & spilled & (pool_gb > 0))[None, :] \
            & (p[None, :] >= ths[:, None])
    return mit, mit.sum(1).astype(np.int64)


# -------------------------------------------------- tradeoff-curve interp --
def interp_tradeoff(x, xp, fp) -> np.ndarray:
    """``np.interp`` with its monotone-``xp`` precondition enforced.

    The seed Fig 18/20 paths interpolated tradeoff curves (UM vs OP)
    straight through ``np.interp``, whose result is silently garbage
    when the curve is not sorted by ``xp`` — model curves need not be
    monotone in the swept parameter.  Sorts (stable) by ``xp`` first;
    for already-sorted inputs this is bitwise ``np.interp``.
    """
    xp = np.asarray(xp, np.float64)
    fp = np.asarray(fp, np.float64)
    order = np.argsort(xp, kind="stable")
    return np.interp(x, xp[order], fp[order])
