"""Telemetry for opaque jobs (Pond §4.2, Figure 12).

Pond's two telemetry sources and their Pond-JAX analogues:

  * core-PMU / TMA counters  ->  roofline counters from the compiled step
    (launch/hlo_analysis.py): memory-bound / collective-bound fractions are
    the direct analogue of TMA "memory bound" pipeline-slot fractions.
    Sampled once per step (paper: once per second, 1ms cost, no
    event-based sampling).
  * hypervisor page-table access-bit scans -> KV-block / buffer touch
    tracking with periodic reset (paper: every 30 min, 10 s cost; here:
    every ``scan_every`` engine steps).  Only *untouched* detection is
    needed, so infrequent resets are fine (§4.2).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

TMA_METRICS = (
    "memory_bound", "dram_bound", "l1_bound", "l2_bound", "l3_bound",
    "store_bound", "core_bound", "frontend_bound", "bad_speculation",
    "retiring", "ipc", "mlp", "llc_miss_per_kilo", "tlb_miss_per_kilo",
    "bw_util", "latency_sensitivity_raw",
)


@dataclasses.dataclass
class StepCounters:
    """One step's roofline counters (the PMU sample)."""
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    step_time_s: float = 0.0
    tokens: int = 0

    def tma_vector(self, peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9):
        """TMA-style boundedness fractions (features for the LI model)."""
        ct = self.flops / peak_flops
        mt = self.bytes / hbm_bw
        xt = self.collective_bytes / ici_bw
        tot = max(ct + mt + xt, 1e-12)
        return {"compute_bound": ct / tot, "memory_bound": mt / tot,
                "collective_bound": xt / tot}


class CounterLog:
    """Per-job rolling PMU log (the distributed counter database)."""

    def __init__(self):
        self._log: dict[str, list] = defaultdict(list)

    def record(self, job: str, counters: StepCounters):
        self._log[job].append(counters)

    def features(self, job: str) -> dict:
        rows = self._log.get(job, [])
        if not rows:
            return {}
        tma = [c.tma_vector() for c in rows]
        return {k: float(np.mean([t[k] for t in tma])) for k in tma[0]}


class AccessBitScanner:
    """Untouched-memory telemetry: access bits with periodic reset."""

    def __init__(self, num_blocks: int, scan_every: int = 64):
        self.bits = np.zeros(num_blocks, bool)
        self.ever = np.zeros(num_blocks, bool)
        self.scan_every = scan_every
        self._step = 0
        self.scans: list[float] = []      # touched fraction per scan

    def touch(self, block_ids):
        self.bits[np.asarray(block_ids, int)] = True
        self.ever[np.asarray(block_ids, int)] = True

    def step(self):
        self._step += 1
        if self._step % self.scan_every == 0:
            self.scans.append(float(self.bits.mean()))
            self.bits[:] = False          # reset access bits (cheap: §5)

    def untouched_fraction(self) -> float:
        return 1.0 - float(self.ever.mean())
