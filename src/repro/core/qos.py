"""QoS monitor + mitigation manager (Pond §4.3 B, Figure 11).

The monitor inspects every running VM/job once per sampling interval:
  B1: query hypervisor + PMU counters (telemetry.CounterLog),
  B2: the sensitivity model decides whether the job exceeds the PDM,
  B3: the mitigation manager triggers a one-time memory reconfiguration —
      the hypervisor disables the virtualization accelerator, copies the
      VM's pool memory to local (50 ms/GB), re-enables it.  After that the
      VM is all-local and never re-pooled (one-time correction, §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.latency_model import migration_seconds


def exceeds_pdm(slowdown, pdm: float):
    """Canonical PDM-violation predicate: slowdown AT the margin counts.

    The paper's tail-latency predicate is inclusive (a VM whose
    slowdown reaches the performance degradation margin has exhausted
    it), matching the monitor's ``p >= threshold`` mitigation trigger
    below.  The seed code used a strict ``>`` in the sensitivity
    labels / misprediction accounting, silently excusing boundary
    workloads — every harm/label site now routes through this
    predicate (see tests/test_latency_engine.py regression).
    Works elementwise on arrays.
    """
    return slowdown >= pdm


@dataclasses.dataclass
class Mitigation:
    vm_id: int
    at: float
    pool_gb: float
    copy_seconds: float


class MitigationManager:
    def __init__(self):
        self.log: list[Mitigation] = []
        self.migrated: set[int] = set()

    def migrate(self, vm_id: int, pool_gb: float, now: float) -> Mitigation:
        m = Mitigation(vm_id, now, pool_gb, migration_seconds(pool_gb))
        self.log.append(m)
        self.migrated.add(vm_id)
        return m


class QoSMonitor:
    """Checks zNUMA spill + model-predicted sensitivity against the PDM."""

    def __init__(self, pdm: float, p_sensitive: Callable[[np.ndarray],
                                                         np.ndarray],
                 threshold: float, mitigation: MitigationManager):
        self.pdm = pdm
        self.p_sensitive = p_sensitive
        self.threshold = threshold
        self.mitigation = mitigation
        self.checks = 0

    def check(self, vm_id: int, pmu: np.ndarray, spilled: bool,
              pool_gb: float, now: float) -> Mitigation | None:
        """spilled: the VM touched pool memory beyond its zNUMA sizing
        (access-bit telemetry).  Pool-backed VMs always count as spilled."""
        self.checks += 1
        if vm_id in self.mitigation.migrated or not spilled or pool_gb <= 0:
            return None
        p = float(self.p_sensitive(pmu[None])[0])
        if p >= self.threshold:          # predicted to exceed the PDM
            return self.mitigation.migrate(vm_id, pool_gb, now)
        return None
