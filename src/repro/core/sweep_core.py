"""Shared sweep core for the event-compiled replay engines.

Every replay engine in ``core/replay_engine.py`` — ``CompiledReplay``
(one trace), ``CompiledReplayBatch`` (K traces, one vmapped scan),
``CompiledReplayStream`` (out-of-core shards, carried state) and
``CompiledReplayStreamBatch`` (K streams, batched carry) — prices
``(server_gb, pool_gb)`` candidates with the SAME integer event-step
kernel.  This module is that kernel plus everything the engines share
around it, so the engine classes stay thin orchestration layers:

* **The dtype-parametric event-step kernel** (:func:`build_sweep`):
  one ``lax.scan`` body covering arrivals (best-fit-by-cores with
  per-group pool checks and the all-local fallback), departures and
  QoS migrations, parametric over the packed state dtype (int32 or
  int16) and over whether the packed state is returned as a carry
  (the streaming variant) or consumed whole (the monolithic variant).

* **A single keyed jit cache** (:func:`get_sweep`): jitted sweeps are
  cached by ``(state_dtype, with_carry, batched)``.  This replaces the
  old ``_JAX_SWEEPS`` dict + ``_JAX_BATCH_SWEEP`` module globals —
  the batch global ignored the state dtype, so batched sweeps always
  ran int32 even when int16 packing applied (fixed here; regression
  test in ``tests/test_sweep_core.py``).  Carry variants are jitted
  with **donated carry arguments**: the shard-to-shard state buffers
  are reused in place on backends that support donation, so the carry
  stays device-resident instead of round-tripping through fresh
  allocations.

* **int16/int32 packing rules** (:func:`pick_state_dtype`): the carry
  packs to int16 — half the sweep's memory traffic — exactly when no
  intermediate can overflow: candidate capacity plus per-VM payload
  headroom within :data:`I16_SAFE`, the best-fit score sentinel above
  every free-cores value, packed slot values in range, and (for
  MIGRATE-bearing traces) the compiled migrate-event pool total
  bounding the fallback-migrate used-pool deficit.

* **Padding buckets** (:func:`bucket_width`, :func:`candidate_chunks`,
  :func:`pad_up`): candidate batches pad to fixed widths
  (2/4/16/32/96), event streams to multiples of 256, server/group
  columns to multiples of 16 and placement slots to multiples of 32,
  so XLA recompiles are rare.

* **Carry pack/unpack** (:func:`init_state`, :func:`lane_capacities`,
  :func:`quantize_capacities`, :func:`assign_slots`): building the
  packed all-free initial state (optionally with a leading trace
  axis for the batched engines), quantizing candidate capacities to
  the int sweep's domain, filling padded candidate lanes, and mapping
  VMs to reusable placement slots sized by peak concurrency.

* **Explicit device placement** (:func:`device_put`): shard event
  tensors and carry state are placed with ``jax.device_put`` so the
  identical code path runs on CPU, GPU or TPU — on accelerators the
  event shards upload one at a time and the carry never leaves the
  device, which is what keeps peak memory bounded by one shard
  (batch) regardless of trace length.

The kernel is bit-exact with respect to the scalar float64 oracle
(``cluster_sim.replay_reject_rate``) because every VM memory quantity
is an integral GB: admission tests like ``free_mem >= local_gb`` are
exactly ``used_mem + local_gb <= floor(server_gb)`` over int32 (see
``docs/replay_engine.md``).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import obs

ARRIVE, DEPART, MIGRATE = 0, 1, 2
PAD = 3               # no-op event kind used to pad the XLA event stream
FAIL, RECOVER = 4, 5  # failure-domain events (EMC/pod blast radius, §4.2);
# no-ops in the plain sweep, resolved in-scan by the failure sweep
# (:func:`build_fail_sweep`).  Sort AFTER same-time VM events: a VM
# departing at the instant of the failure has already left.
JAX_CHUNK = 96        # max candidate bucket per compiled sweep
BUCKETS = (2, 4, 16, 32, JAX_CHUNK)   # padded candidate widths (lazy
# compiles, one per width actually used; the small buckets matter for
# narrow probe batches — bracket checks and final-rate evaluations are
# fixed-cost-dominated per sweep, so padding 1-2 probes to 16 lanes
# would waste most of the sweep)
EVENT_PAD = 256       # event-stream pad granularity
LANE_PAD = 16         # server/group column pad granularity
SLOT_PAD = 32         # placement-slot pad granularity
I32_BIG = 1 << 30     # "infinite" capacity in the int32 sweep
I16_BIG = 1 << 14     # best-fit score sentinel in the int16 sweep
I16_SAFE = 30000      # int16 headroom bound: capacity + payload must fit


# --------------------------------------------------------------- jit cache --
_JAX_OK = None        # tri-state: None unknown, then True/False
_SWEEPS: dict = {}    # (state_dtype, with_carry, batched) -> jitted sweep


def _jit_key_name(family: str, state_dtype: str, **flags) -> str:
    """Counter-name stem for one jit-cache key, e.g.
    ``jit.sweep.int32.carry1.batched0`` — the cache accessors append
    ``.hit``/``.miss``; the keyed build/lower spans share the stem."""
    bits = [f"{k}{int(v)}" if isinstance(v, bool) else str(v)
            for k, v in flags.items()]
    return ".".join(["jit", family, state_dtype] + bits)


class _FirstCallTimer:
    """Times the FIRST invocation of a freshly jitted sweep — XLA
    tracing + lowering + compile all happen there — as a
    ``jit.<family>.<key>.lower`` span, then delegates with one
    attribute hop.
    Installed only while a recorder is live (cache misses with tracing
    disabled store the bare jitted fn, zero steady-state overhead)."""
    __slots__ = ("fn", "name", "_first")

    def __init__(self, fn, name):
        self.fn = fn
        self.name = name
        self._first = True

    def __call__(self, *args):
        if self._first:
            self._first = False
            with obs.get_recorder().span(self.name):
                return self.fn(*args)
        return self.fn(*args)


def jax_importable() -> bool:
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax                               # noqa: F401
            _JAX_OK = True
        except Exception:                            # pragma: no cover
            _JAX_OK = False
    return _JAX_OK


def build_sweep(state_dtype: str = "int32", with_carry: bool = False):
    """Build the (unjitted) integer event-sweep function.

    Because every VM memory quantity is an integral GB, admission tests
    like ``free_mem >= local_gb`` are equivalent to
    ``used_mem + local_gb <= floor(server_gb)`` over int32 — so the whole
    sweep runs in int32 under JAX's default x32 config and still matches
    the float64 oracle bit-for-bit.  Placement state lives in a
    ``(n_slots, C)`` array (VMs are mapped to reusable slots sized by
    peak concurrency, far smaller than n_vms) updated with leading-axis
    dynamic_update_slice so the scan carry stays in place.

    ``state_dtype="int16"`` packs the carry (free cores, used local GB,
    used pool GB, placement slots) to int16, halving the sweep's memory
    traffic.  The int16 sweep is bit-equivalent to int32 whenever no
    intermediate can overflow; callers must check
    :func:`pick_state_dtype` (capacity + per-VM payload headroom within
    :data:`I16_SAFE`) before selecting it.  Candidate events stay int32
    and are cast inside the body; the reject counters stay int32 (a
    trace can reject more than 2^15 VMs).

    ``with_carry=True`` returns the shard variant used by the streaming
    engines: it takes AND returns the full packed state, so consecutive
    time-windowed shards thread the carry.

    The returned function is pure over jax arrays: :func:`get_sweep`
    jits it directly, or vmaps it over a leading trace axis first
    (``batched=True``) so K traces price their candidate batches in ONE
    ``lax.scan``.
    """
    import jax.numpy as jnp
    from jax import lax
    dt = jnp.int16 if state_dtype == "int16" else jnp.int32
    big = jnp.asarray(I16_BIG if state_dtype == "int16" else I32_BIG, dt)
    zero = jnp.asarray(0, dt)

    def body(carry, ev):
        fc, um, up, slots, rejects, sgb, pgb, group_of = carry
        kind, sl, c, l, p, m = ev
        c, l, p, m = (c.astype(dt), l.astype(dt), p.astype(dt),
                      m.astype(dt))
        is_arr, is_dep, is_mig = kind == ARRIVE, kind == DEPART, \
            kind == MIGRATE
        val = slots[sl]                              # (C,) packed s*2+mig
        has = val >= 0
        s_cur = jnp.where(has, val >> 1, 0)
        mg_cur = has & ((val & 1) == 1)
        cols = jnp.arange(fc.shape[1], dtype=jnp.int32)
        gcols = jnp.arange(up.shape[1], dtype=jnp.int32)
        # admission: best fit by cores among servers with local memory
        # room and group pool room (same mask as the scalar oracle)
        upg = up[:, group_of]
        ok = (fc >= c) & (um + l <= sgb[:, None]) & (upg + p <= pgb[:, None])
        score = jnp.where(ok, fc, big)
        s1 = jnp.argmin(score, 1).astype(jnp.int32)
        feas1 = jnp.take_along_axis(score, s1[:, None], 1)[:, 0] < big
        # pool short -> control-plane fallback: start the VM all-local
        ok2 = (fc >= c) & (um + m <= sgb[:, None])
        score2 = jnp.where(ok2, fc, big)
        s2 = jnp.argmin(score2, 1).astype(jnp.int32)
        feas2 = jnp.take_along_axis(score2, s2[:, None], 1)[:, 0] < big
        sel = jnp.where(feas1, s1, s2)
        place = feas1 | feas2
        s_aff = jnp.where(is_arr, sel, s_cur)
        act_arr = is_arr & place
        act_dep = is_dep & has
        um_s = jnp.take_along_axis(um, s_aff[:, None], 1)[:, 0]
        act_mig = is_mig & has & (um_s + p <= sgb)   # QoS: pool -> local
        oh = cols[None, :] == s_aff[:, None]
        dfc = jnp.where(act_dep, c, zero) - jnp.where(act_arr, c, zero)
        dum = (jnp.where(act_arr, jnp.where(feas1, l, m), zero)
               - jnp.where(act_dep, jnp.where(mg_cur, m, l), zero)
               + jnp.where(act_mig, p, zero))
        g_aff = group_of[s_aff]
        goh = gcols[None, :] == g_aff[:, None]
        dup = (jnp.where(act_arr & feas1, p, zero)
               - jnp.where(act_dep & ~mg_cur, p, zero)
               - jnp.where(act_mig, p, zero))
        fc = fc + oh * dfc[:, None]
        um = um + oh * dum[:, None]
        up = up + goh * dup[:, None]
        aval = jnp.where(place, sel * 2 + jnp.where(feas1, 0, 1), -1)
        new_val = jnp.where(is_arr, aval,
                            jnp.where(is_dep, -1,
                                      jnp.where(act_mig, val | 1, val)))
        slots = lax.dynamic_update_index_in_dim(
            slots, new_val.astype(slots.dtype), sl, 0)
        rejects = rejects + (is_arr & ~feas1 & ~feas2)
        return (fc, um, up, slots, rejects, sgb, pgb, group_of), None

    def sweep_carry(evs, group_of, fc0, um0, up0, slots0, rej0, sgb, pgb):
        init = (fc0, um0, up0, slots0, rej0, sgb, pgb, group_of)
        out, _ = lax.scan(body, init, evs)
        return out[0], out[1], out[2], out[3], out[4]

    def sweep(evs, group_of, fc0, um0, up0, slots0, sgb, pgb):
        init = (fc0, um0, up0, slots0,
                jnp.zeros(sgb.shape[0], jnp.int32), sgb, pgb, group_of)
        out, _ = lax.scan(body, init, evs)
        return out[4]

    return sweep_carry if with_carry else sweep


#: positions of the packed carry in the ``with_carry`` sweep signature
#: ``(evs, group_of, fc0, um0, up0, slots0, rej0, sgb, pgb)`` — donated
#: so the shard-to-shard state is reused in place (device-resident)
_CARRY_ARGNUMS = (2, 3, 4, 5, 6)


def get_sweep(state_dtype: str = "int32", *, with_carry: bool = False,
              batched: bool = False, mesh=None,
              shard_axis: str = "trace"):
    """Jitted sweep from the keyed cache, or None when jax is missing.

    ONE cache keyed by ``(state_dtype, with_carry, batched)`` serves
    every engine — compiled lazily, one jit per key actually used:

    * ``(dt, False, False)`` — monolithic single-trace sweep
      (``CompiledReplay``).
    * ``(dt, True, False)`` — shard sweep with carried state
      (``CompiledReplayStream``); carry args donated.
    * ``(dt, False, True)`` — vmapped over a leading trace axis with a
      SHARED all-free initial state (``CompiledReplayBatch``): per-trace
      event streams and candidate capacities, one scan with a batched
      carry for K traces.
    * ``(dt, True, True)`` — vmapped shard sweep with a PER-TRACE carry
      (``CompiledReplayStreamBatch``): K streams thread one batched
      carry shard-to-shard; carry args donated.

    With ``mesh`` set (a 1-D :func:`shard_mesh`), the (possibly
    vmapped) sweep is additionally wrapped in ``shard_map`` over the
    mesh's ``"shard"`` axis before jitting — partitioning either the
    leading trace axis (``shard_axis="trace"``: per-device slices of
    the K event rows, capacities and carry) or the candidate-lane axis
    (``shard_axis="lane"``: events replicated, state lanes split).
    Lanes and trace rows replay independently (the best-fit argmin
    runs over the never-sharded server axis), so sharded sweeps are
    bit-exact vs the single-device jit; sharded variants get their own
    cache keys (``(..., device_ids, axis)``).
    """
    if not jax_importable():
        return None
    if mesh is None:
        key = (state_dtype, with_carry, batched)
        flags = dict(carry=with_carry, batched=batched)
    else:
        key = (state_dtype, with_carry, batched, _mesh_key(mesh),
               shard_axis)
        flags = dict(carry=with_carry, batched=batched,
                     mesh=f"{shard_axis}{mesh.size}")
    fn = _SWEEPS.get(key)
    rec = obs.get_recorder()
    if fn is None:
        import jax
        stem = _jit_key_name("sweep", state_dtype, **flags)
        if rec.enabled:
            rec.count(stem + ".miss")
        with rec.span(stem + ".build"):
            base = build_sweep(state_dtype, with_carry)
            if batched and with_carry:
                base = jax.vmap(base, in_axes=((0, 0, 0, 0, 0, 0), None,
                                               0, 0, 0, 0, 0, 0, 0))
            elif batched:
                base = jax.vmap(base,
                                in_axes=((0, 0, 0, 0, 0, 0), None,
                                         None, None, None, None, 0, 0))
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                in_specs, out_specs = _plain_shard_specs(
                    jax.sharding.PartitionSpec, with_carry, batched,
                    shard_axis)
                base = shard_map(base, mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
            fn = jax.jit(base, donate_argnums=_CARRY_ARGNUMS
                         if with_carry else ())
        if rec.enabled:
            fn = _FirstCallTimer(fn, stem + ".lower")
        _SWEEPS[key] = fn
    elif rec.enabled:
        rec.count(_jit_key_name("sweep", state_dtype, **flags) + ".hit")
    return fn


def jit_cache_keys() -> list:
    """Keys compiled so far (introspection for tests/benchmarks)."""
    return sorted(_SWEEPS, key=repr)


# ------------------------------------------------------------ failure sweep --
_FAIL_SWEEPS: dict = {}   # (state_dtype, mitigation, batched, with_dist)

MITIGATIONS = ("remigrate", "kill")


def build_fail_sweep(state_dtype: str = "int32",
                     mitigation: str = "remigrate",
                     with_dist: bool = True):
    """Build the (unjitted) failure-aware event sweep.

    Same integer admission/departure/migration semantics as
    :func:`build_sweep`, plus the Pond §4.2 failure model resolved
    inside the scan step:

    * Events carry two extra int32 streams: ``x`` (the VM's departure
      minute at ARRIVE; the failure minute at FAIL) and ``dmn`` (the
      failure domain at FAIL/RECOVER, -1 otherwise).  One failure
      domain per EMC group.
    * While a domain is down (between its FAIL and RECOVER) its pool
      capacity is offline: arrivals needing pool slices there fail the
      pooled admission test and take the all-local fallback (or
      reject), per §4.3.
    * ``FAIL(d)``: every live VM holding pool slices in domain ``d``
      is affected (the blast-radius rule).  ``mitigation="kill"``
      terminates them all; ``mitigation="remigrate"`` pulls each
      server's affected pool into host-local DRAM when the server's
      free local memory covers its TOTAL affected pool demand
      (all-or-nothing per server — the host either absorbs its pooled
      pages or loses those VMs), killing the rest.  Either way the
      domain's EMC slices are lost: its used-pool column resets to 0.
    * Availability counters ride in the carry per candidate lane:
      VMs affected, VMs killed, VMs remigrated, and VM-minutes lost
      (``departure_minute - failure_minute`` summed over kills, int32).
      With ``with_dist=True`` the scan also emits the per-event
      affected count (zeros off FAIL events), giving the
      VMs-affected-per-failure distribution.

    The blast-radius step scans the whole ``(n_slots, C)`` placement
    array at EVERY event, so this kernel costs ~O(n_slots) more per
    event than the plain sweep — use :func:`get_sweep` when no failure
    events are present.  Bit-exact against the scalar oracle
    ``cluster_sim.replay_with_failures`` for integral-GB traces
    (``tests/test_failures.py``).
    """
    if mitigation not in MITIGATIONS:
        raise ValueError(f"mitigation must be one of {MITIGATIONS}")
    import jax.numpy as jnp
    from jax import lax
    dt = jnp.int16 if state_dtype == "int16" else jnp.int32
    big = jnp.asarray(I16_BIG if state_dtype == "int16" else I32_BIG, dt)
    zero = jnp.asarray(0, dt)
    remigrate = mitigation == "remigrate"

    def body(carry, ev):
        (fc, um, up, slots, rejects, slot_c, slot_l, slot_p, slot_dep,
         dom_down, affected, killed, remig, lost_min,
         sgb, pgb, group_of) = carry
        kind, sl, c, l, p, m, x, dmn = ev            # all int32
        ci, li, pi = c, l, p                         # int32 bookkeeping
        c, l, p, m = (c.astype(dt), l.astype(dt), p.astype(dt),
                      m.astype(dt))
        is_arr, is_dep, is_mig = kind == ARRIVE, kind == DEPART, \
            kind == MIGRATE
        is_fail, is_rec = kind == FAIL, kind == RECOVER
        val = slots[sl]                              # (C,) packed s*2+mig
        has = val >= 0
        s_cur = jnp.where(has, val >> 1, 0)
        mg_cur = has & ((val & 1) == 1)
        cols = jnp.arange(fc.shape[1], dtype=jnp.int32)
        gcols = jnp.arange(up.shape[1], dtype=jnp.int32)
        # admission as the plain sweep, plus: a down domain has no EMC
        # slices to grant, so pool-bearing arrivals skip its servers
        upg = up[:, group_of]
        dom_ok = (pi == 0) | (dom_down[group_of] == 0)[None, :]
        ok = ((fc >= c) & (um + l <= sgb[:, None])
              & (upg + p <= pgb[:, None]) & dom_ok)
        score = jnp.where(ok, fc, big)
        s1 = jnp.argmin(score, 1).astype(jnp.int32)
        feas1 = jnp.take_along_axis(score, s1[:, None], 1)[:, 0] < big
        ok2 = (fc >= c) & (um + m <= sgb[:, None])
        score2 = jnp.where(ok2, fc, big)
        s2 = jnp.argmin(score2, 1).astype(jnp.int32)
        feas2 = jnp.take_along_axis(score2, s2[:, None], 1)[:, 0] < big
        sel = jnp.where(feas1, s1, s2)
        place = feas1 | feas2
        s_aff = jnp.where(is_arr, sel, s_cur)
        act_arr = is_arr & place
        act_dep = is_dep & has
        um_s = jnp.take_along_axis(um, s_aff[:, None], 1)[:, 0]
        act_mig = is_mig & has & (um_s + p <= sgb)   # QoS: pool -> local
        oh = cols[None, :] == s_aff[:, None]
        dfc = jnp.where(act_dep, c, zero) - jnp.where(act_arr, c, zero)
        dum = (jnp.where(act_arr, jnp.where(feas1, l, m), zero)
               - jnp.where(act_dep, jnp.where(mg_cur, m, l), zero)
               + jnp.where(act_mig, p, zero))
        g_aff = group_of[s_aff]
        goh = gcols[None, :] == g_aff[:, None]
        dup = (jnp.where(act_arr & feas1, p, zero)
               - jnp.where(act_dep & ~mg_cur, p, zero)
               - jnp.where(act_mig, p, zero))
        fc = fc + oh * dfc[:, None]
        um = um + oh * dum[:, None]
        up = up + goh * dup[:, None]
        aval = jnp.where(place, sel * 2 + jnp.where(feas1, 0, 1), -1)
        new_val = jnp.where(is_arr, aval,
                            jnp.where(is_dep, -1,
                                      jnp.where(act_mig, val | 1, val)))
        slots = lax.dynamic_update_index_in_dim(
            slots, new_val.astype(slots.dtype), sl, 0)
        rejects = rejects + (is_arr & ~feas1 & ~feas2)
        # ARRIVE records the slot's payload — shared across lanes (slot
        # assignment is host-side, identical in every lane; lanes where
        # the VM was rejected keep val < 0 and never read it)
        slot_c = lax.dynamic_update_index_in_dim(
            slot_c, jnp.where(is_arr, ci, slot_c[sl]), sl, 0)
        slot_l = lax.dynamic_update_index_in_dim(
            slot_l, jnp.where(is_arr, li, slot_l[sl]), sl, 0)
        slot_p = lax.dynamic_update_index_in_dim(
            slot_p, jnp.where(is_arr, pi, slot_p[sl]), sl, 0)
        slot_dep = lax.dynamic_update_index_in_dim(
            slot_dep, jnp.where(is_arr, x, slot_dep[sl]), sl, 0)
        # ------- blast radius: whole-slot-array step (no-op off FAIL) --
        live = slots >= 0                            # (n_slots, C)
        srv = jnp.where(live, (slots >> 1).astype(jnp.int32), 0)
        pooled = live & ((slots & 1) == 0) & (slot_p[:, None] > 0)
        aff = is_fail & pooled & (group_of[srv] == dmn)
        lanes = jnp.arange(fc.shape[0], dtype=jnp.int32)[None, :]
        if remigrate:
            # all-or-nothing per server: total affected pool demand on
            # the server must fit its free local memory (checked in
            # int32 — per-server sums can exceed the int16 domain)
            demand = jnp.zeros(fc.shape, jnp.int32).at[lanes, srv].add(
                jnp.where(aff, slot_p[:, None], 0))
            fits = (um.astype(jnp.int32) + demand
                    <= sgb.astype(jnp.int32)[:, None])
            rem_mask = aff & fits[lanes, srv]
            kill_mask = aff & ~fits[lanes, srv]
        else:
            rem_mask = jnp.zeros_like(aff)
            kill_mask = aff
        dfc_f = jnp.zeros(fc.shape, jnp.int32).at[lanes, srv].add(
            jnp.where(kill_mask, slot_c[:, None], 0))
        dum_f = (jnp.zeros(fc.shape, jnp.int32).at[lanes, srv].add(
            jnp.where(rem_mask, slot_p[:, None], 0))
            - jnp.zeros(fc.shape, jnp.int32).at[lanes, srv].add(
                jnp.where(kill_mask, slot_l[:, None], 0)))
        fc = fc + dfc_f.astype(dt)
        um = um + dum_f.astype(dt)
        # the failed domain loses every slice: used pool resets to 0
        # (its pool comes back EMPTY at RECOVER)
        up = jnp.where(is_fail & (gcols == dmn)[None, :], zero, up)
        slots = jnp.where(kill_mask, jnp.asarray(-1, slots.dtype),
                          jnp.where(rem_mask, slots | 1, slots))
        dom_down = jnp.where((is_fail | is_rec) & (gcols == dmn),
                             jnp.where(is_fail, 1, 0), dom_down)
        n_aff = jnp.sum(aff, 0, dtype=jnp.int32)     # (C,)
        affected = affected + n_aff
        killed = killed + jnp.sum(kill_mask, 0, dtype=jnp.int32)
        remig = remig + jnp.sum(rem_mask, 0, dtype=jnp.int32)
        lost_min = lost_min + jnp.sum(
            jnp.where(kill_mask,
                      jnp.maximum(slot_dep - x, 0)[:, None], 0),
            0, dtype=jnp.int32)
        new_carry = (fc, um, up, slots, rejects, slot_c, slot_l, slot_p,
                     slot_dep, dom_down, affected, killed, remig,
                     lost_min, sgb, pgb, group_of)
        return new_carry, (n_aff if with_dist else None)

    def sweep(evs, group_of, fc0, um0, up0, slots0,
              slot_c0, slot_l0, slot_p0, slot_dep0, dom0, sgb, pgb):
        zc = jnp.zeros(sgb.shape[0], jnp.int32)
        init = (fc0, um0, up0, slots0, zc, slot_c0, slot_l0, slot_p0,
                slot_dep0, dom0, zc, zc, zc, zc, sgb, pgb, group_of)
        out, ys = lax.scan(body, init, evs)
        return (out[4], out[10], out[11], out[12], out[13],
                ys if with_dist else None)

    return sweep


def get_fail_sweep(state_dtype: str = "int32",
                   mitigation: str = "remigrate", *,
                   batched: bool = False, with_dist: bool = True):
    """Jitted failure sweep from the keyed cache (None without jax).

    Keyed by ``(state_dtype, mitigation, batched, with_dist)``; the
    batched variant vmaps over a leading trace axis — per-trace event
    streams (each with its own merged failure schedule), per-trace
    packed state, shared group map — so K (trace, schedule) rows price
    their candidate batches in ONE scan (the
    ``benchmarks/fig_availability.py`` frontier pass).
    """
    if not jax_importable():
        return None
    key = (state_dtype, mitigation, batched, with_dist)
    fn = _FAIL_SWEEPS.get(key)
    rec = obs.get_recorder()
    if fn is None:
        import jax
        stem = _jit_key_name("fail", state_dtype, mitigation=mitigation,
                             batched=batched, dist=with_dist)
        if rec.enabled:
            rec.count(stem + ".miss")
        with rec.span(stem + ".build"):
            base = build_fail_sweep(state_dtype, mitigation, with_dist)
            if batched:
                base = jax.vmap(base, in_axes=((0,) * 8, None,
                                               0, 0, 0, 0, 0, 0, 0, 0, 0,
                                               0, 0))
            fn = jax.jit(base)
        if rec.enabled:
            fn = _FirstCallTimer(fn, stem + ".lower")
        _FAIL_SWEEPS[key] = fn
    elif rec.enabled:
        rec.count(_jit_key_name("fail", state_dtype,
                                mitigation=mitigation, batched=batched,
                                dist=with_dist) + ".hit")
    return fn


def init_fail_state(n_slots: int, g_pad: int,
                    k: int | None = None) -> tuple:
    """All-empty failure-sweep extras: per-slot payload records
    (cores, local GB, pool GB, departure minute — int32, shared across
    candidate lanes) and the per-domain down flags.  With ``k`` set,
    every array gains a leading trace axis (batched variant)."""
    out = (np.zeros(n_slots, np.int32), np.zeros(n_slots, np.int32),
           np.zeros(n_slots, np.int32), np.zeros(n_slots, np.int32),
           np.zeros(g_pad, np.int32))
    if k is None:
        return out
    return tuple(np.broadcast_to(a, (k,) + a.shape).copy() for a in out)


# --------------------------------------------------------------- pod sweep --
_POD_SWEEPS: dict = {}   # (state_dtype, with_carry, batched) -> jitted


def build_pod_sweep(state_dtype: str = "int32",
                    with_carry: bool = False):
    """Build the (unjitted) multi-pod fleet event sweep.

    The pod generalization of :func:`build_sweep`: the per-group
    used-pool row becomes a per-POD vector ``up (C, P)`` and the single
    ``group_of`` map becomes a PER-LANE incidence tensor
    ``inc (C, S, F)`` — row ``(ci, s)`` lists the pods server ``s`` can
    reach in lane ``ci``'s topology, in preference order, ``-1``
    padded (see ``core/topology.py``).  Candidate lanes therefore
    carry ``(server_gb, per-pod pool_gb, topology)`` triples: one scan
    prices a whole topology grid.

    Semantics (the contract ``cluster_sim.replay_multi_pool``
    replicates in float64, bit-exact on integral-GB traces):

    * ARRIVE admits a server when cores + local memory fit AND
      (``pool_gb == 0`` or SOME reachable pod has room for the WHOLE
      pool demand); best fit by cores, first min.  The granting pod is
      the FIRST listed pod with room on the chosen server; ``-1``
      (no grant) for pool-free VMs.  No pooled-admissible server ->
      the all-local fallback, else reject (§4.3 unchanged).
    * DEPART returns the local share to the server and the pool share
      to the RECORDED granting pod (nothing for migrated/fallback
      VMs, as the single-pool kernel).
    * MIGRATE keeps the oracle quirk verbatim — placed VM + local room
      triggers the move with no migrated-set check — returning pool to
      the recorded granting pod; a fallback-placed VM (no grant) pays
      the pool back to its server's FIRST listed pod, or skips the
      pool update entirely on a pod-less server (the local move still
      happens).  The per-pod used-pool can thus go NEGATIVE, bounded
      by the same ``mig_pool_sum`` deficit as the single-pool kernel.

    A second ``(n_slots, C)`` slot array carries the granting pod per
    placement (``-1`` none), extending the int16 packing rules by one
    bound: pod ids must stay below the int16 sentinel
    (:func:`pick_pod_state_dtype`).
    """
    import jax.numpy as jnp
    from jax import lax
    dt = jnp.int16 if state_dtype == "int16" else jnp.int32
    big = jnp.asarray(I16_BIG if state_dtype == "int16" else I32_BIG, dt)
    zero = jnp.asarray(0, dt)

    def body(carry, ev):
        fc, um, up, slots, pods, rejects, sgb, pgb, inc = carry
        kind, sl, c, l, p, m = ev
        pi = p                                       # int32 (shortcuts)
        c, l, p, m = (c.astype(dt), l.astype(dt), p.astype(dt),
                      m.astype(dt))
        is_arr, is_dep, is_mig = kind == ARRIVE, kind == DEPART, \
            kind == MIGRATE
        val = slots[sl]                              # (C,) packed s*2+mig
        has = val >= 0
        s_cur = jnp.where(has, val >> 1, 0)
        mg_cur = has & ((val & 1) == 1)
        podv = pods[sl].astype(jnp.int32)            # (C,) granting pod
        n_c, n_s = fc.shape
        n_f = inc.shape[2]
        cols = jnp.arange(n_s, dtype=jnp.int32)
        pcols = jnp.arange(up.shape[1], dtype=jnp.int32)
        # per-(lane, server, fanout) pod fit: gather each listed pod's
        # used pool + capacity; -1 padding entries never fit
        inc_flat = inc.reshape(n_c, n_s * n_f)
        valid = inc_flat >= 0
        idx = jnp.maximum(inc_flat, 0)
        upr = jnp.take_along_axis(up, idx, axis=1)
        pgr = jnp.take_along_axis(pgb, idx, axis=1)
        fits = (valid & (upr + p <= pgr)).reshape(n_c, n_s, n_f)
        pool_ok = (pi == 0) | fits.any(-1)           # (C, S)
        ok = (fc >= c) & (um + l <= sgb[:, None]) & pool_ok
        score = jnp.where(ok, fc, big)
        s1 = jnp.argmin(score, 1).astype(jnp.int32)
        feas1 = jnp.take_along_axis(score, s1[:, None], 1)[:, 0] < big
        # pool short -> control-plane fallback: start the VM all-local
        ok2 = (fc >= c) & (um + m <= sgb[:, None])
        score2 = jnp.where(ok2, fc, big)
        s2 = jnp.argmin(score2, 1).astype(jnp.int32)
        feas2 = jnp.take_along_axis(score2, s2[:, None], 1)[:, 0] < big
        sel = jnp.where(feas1, s1, s2)
        place = feas1 | feas2
        s_aff = jnp.where(is_arr, sel, s_cur)
        act_arr = is_arr & place
        act_dep = is_dep & has
        um_s = jnp.take_along_axis(um, s_aff[:, None], 1)[:, 0]
        act_mig = is_mig & has & (um_s + p <= sgb)   # QoS: pool -> local
        oh = cols[None, :] == s_aff[:, None]
        dfc = jnp.where(act_dep, c, zero) - jnp.where(act_arr, c, zero)
        dum = (jnp.where(act_arr, jnp.where(feas1, l, m), zero)
               - jnp.where(act_dep, jnp.where(mg_cur, m, l), zero)
               + jnp.where(act_mig, p, zero))
        fc = fc + oh * dfc[:, None]
        um = um + oh * dum[:, None]
        # granting pod: first listed pod with room on the chosen server
        # (argmax of bool = first True; masked off unless a pooled
        # admission actually happened)
        f_sel = jnp.argmax(fits, axis=-1).astype(jnp.int32)   # (C, S)
        pod_srv = jnp.take_along_axis(
            inc, f_sel[:, :, None], axis=2)[:, :, 0]          # (C, S)
        pod_arr = jnp.take_along_axis(pod_srv, sel[:, None], 1)[:, 0]
        arr_tgt = jnp.where(act_arr & feas1 & (pi > 0), pod_arr, -1)
        dep_tgt = jnp.where(act_dep & ~mg_cur, podv, -1)
        first_pod = jnp.take_along_axis(
            inc[:, :, 0], s_aff[:, None], 1)[:, 0]            # (C,)
        mig_tgt = jnp.where(act_mig,
                            jnp.where(podv >= 0, podv, first_pod), -1)
        up = (up
              + jnp.where(pcols[None, :] == arr_tgt[:, None], p, zero)
              - jnp.where(pcols[None, :] == dep_tgt[:, None], p, zero)
              - jnp.where(pcols[None, :] == mig_tgt[:, None], p, zero))
        aval = jnp.where(place, sel * 2 + jnp.where(feas1, 0, 1), -1)
        new_val = jnp.where(is_arr, aval,
                            jnp.where(is_dep, -1,
                                      jnp.where(act_mig, val | 1, val)))
        slots = lax.dynamic_update_index_in_dim(
            slots, new_val.astype(slots.dtype), sl, 0)
        new_pod = jnp.where(is_arr, arr_tgt,
                            jnp.where(is_dep, -1, podv))
        pods = lax.dynamic_update_index_in_dim(
            pods, new_pod.astype(pods.dtype), sl, 0)
        rejects = rejects + (is_arr & ~feas1 & ~feas2)
        return (fc, um, up, slots, pods, rejects, sgb, pgb, inc), None

    def sweep_carry(evs, inc, fc0, um0, up0, slots0, pods0, rej0,
                    sgb, pgb):
        init = (fc0, um0, up0, slots0, pods0, rej0, sgb, pgb, inc)
        out, _ = lax.scan(body, init, evs)
        return out[0], out[1], out[2], out[3], out[4], out[5]

    def sweep(evs, inc, fc0, um0, up0, slots0, pods0, sgb, pgb):
        init = (fc0, um0, up0, slots0, pods0,
                jnp.zeros(sgb.shape[0], jnp.int32), sgb, pgb, inc)
        out, _ = lax.scan(body, init, evs)
        return out[5]

    return sweep_carry if with_carry else sweep


#: packed-carry positions in the ``with_carry`` pod-sweep signature
#: ``(evs, inc, fc0, um0, up0, slots0, pods0, rej0, sgb, pgb)``
_POD_CARRY_ARGNUMS = (2, 3, 4, 5, 6, 7)


def get_pod_sweep(state_dtype: str = "int32", *,
                  with_carry: bool = False, batched: bool = False,
                  mesh=None):
    """Jitted pod sweep from the keyed cache (None without jax).

    Same four variants as :func:`get_sweep` — monolithic, carry
    (donated state), vmapped batch with shared init, vmapped batch
    with per-trace carry — keyed by ``(state_dtype, with_carry,
    batched)``.  The incidence tensor is shared across traces in the
    batched variants (one topology grid, K traces); candidate
    capacities stay per trace.

    ``mesh`` (batched variants only) wraps the vmapped sweep in
    ``shard_map`` over the leading trace axis, like
    :func:`get_sweep` with ``shard_axis="trace"`` — the fleet engines
    shard only the trace axis (the incidence tensor stays replicated).
    """
    if not jax_importable():
        return None
    if mesh is None:
        key = (state_dtype, with_carry, batched)
        flags = dict(carry=with_carry, batched=batched)
    else:
        key = (state_dtype, with_carry, batched, _mesh_key(mesh),
               "trace")
        flags = dict(carry=with_carry, batched=batched,
                     mesh=f"trace{mesh.size}")
    fn = _POD_SWEEPS.get(key)
    rec = obs.get_recorder()
    if fn is None:
        import jax
        stem = _jit_key_name("pod", state_dtype, **flags)
        if rec.enabled:
            rec.count(stem + ".miss")
        with rec.span(stem + ".build"):
            base = build_pod_sweep(state_dtype, with_carry)
            if batched and with_carry:
                base = jax.vmap(base, in_axes=((0, 0, 0, 0, 0, 0), None,
                                               0, 0, 0, 0, 0, 0, 0, 0))
            elif batched:
                base = jax.vmap(base, in_axes=((0, 0, 0, 0, 0, 0), None,
                                               None, None, None, None,
                                               None, 0, 0))
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                in_specs, out_specs = _pod_shard_specs(
                    jax.sharding.PartitionSpec, with_carry)
                base = shard_map(base, mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
            fn = jax.jit(base, donate_argnums=_POD_CARRY_ARGNUMS
                         if with_carry else ())
        if rec.enabled:
            fn = _FirstCallTimer(fn, stem + ".lower")
        _POD_SWEEPS[key] = fn
    elif rec.enabled:
        rec.count(_jit_key_name("pod", state_dtype, **flags) + ".hit")
    return fn


def pod_jit_cache_keys() -> list:
    """Pod-sweep keys compiled so far (introspection for tests)."""
    return sorted(_POD_SWEEPS, key=repr)


def pick_pod_state_dtype(cores_per_server: float, n_servers: int,
                         sgb_i: np.ndarray, pod_caps_i: np.ndarray,
                         pay_mem_max: float, pay_pool_max: float,
                         mig_pool_sum: float, n_pods: int) -> str:
    """int16/int32 packing rule for the pod sweep.

    The single-pool rules (:func:`pick_state_dtype`) applied with the
    per-pod capacity maxima standing in for the pool column — the
    fallback-migrate deficit bound holds per pod since every deficit
    subtraction lands on exactly one pod — plus one pod-axis bound:
    the granting-pod slot array stores pod ids, so ``n_pods`` must
    stay below the int16 sentinel.
    """
    if n_pods >= I16_BIG:
        return "int32"
    return pick_state_dtype(cores_per_server, n_servers, sgb_i,
                            np.asarray(pod_caps_i).ravel(),
                            pay_mem_max, pay_pool_max, mig_pool_sum)


def pod_lane_arrays(sgb_i: np.ndarray, pgb_i: np.ndarray,
                    inc: np.ndarray, lo: int, hi: int, width: int,
                    np_dt) -> tuple:
    """One candidate chunk's (server_gb, per-pod pool_gb, incidence)
    lane arrays, padded to ``width`` lanes by replicating the chunk's
    last candidate (same no-new-control-flow rule as
    :func:`lane_capacities`).  ``pgb_i`` is ``(n, P)``, ``inc`` is
    ``(n, s_pad, F)`` int32."""
    sgb = np.full(width, sgb_i[hi - 1], np_dt)
    sgb[:hi - lo] = sgb_i[lo:hi]
    pgb = np.repeat(pgb_i[hi - 1:hi], width, 0).astype(np_dt)
    pgb[:hi - lo] = pgb_i[lo:hi]
    incw = np.repeat(inc[hi - 1:hi], width, 0)
    incw[:hi - lo] = inc[lo:hi]
    return sgb, pgb, np.ascontiguousarray(incw, np.int32)


def init_pod_state(width: int, n_servers: int, cores_per_server: float,
                   s_pad: int, p_pad: int, n_slots: int, np_dt,
                   k: int | None = None) -> tuple:
    """Packed all-free initial pod-sweep state: the plain
    :func:`init_state` arrays with the used-pool row widened to the
    padded pod axis plus the granting-pod slot array (``-1`` = no
    grant).  With ``k`` set, every array gains a leading trace axis."""
    fc0, um0, _, slots0, rej0 = init_state(
        width, n_servers, cores_per_server, s_pad, 1, n_slots, np_dt)
    up0 = np.zeros((width, p_pad), np_dt)
    pods0 = np.full((n_slots, width), -1, np_dt)
    out = (fc0, um0, up0, slots0, pods0, rej0)
    if k is None:
        return out
    return tuple(np.broadcast_to(a, (k,) + a.shape).copy()
                 for a in out)


# --------------------------------------------------------- invariant guard --
class SweepInvariantError(RuntimeError):
    """A sweep invariant failed under ``POND_DEBUG_INVARIANTS=1``.

    Structured: ``what`` names the violated invariant, ``shard``/
    ``lane`` (and ``trace`` for batched sweeps) locate the first
    offending state entry.
    """

    def __init__(self, what: str, *, shard: int, lane: int,
                 trace: int | None = None, detail: str = ""):
        self.what, self.shard, self.lane, self.trace = \
            what, shard, lane, trace
        loc = f"shard {shard}, lane {lane}"
        if trace is not None:
            loc = f"shard {shard}, trace {trace}, lane {lane}"
        msg = f"sweep invariant violated: {what} at {loc}"
        super().__init__(msg + (f" ({detail})" if detail else ""))


def invariants_enabled() -> bool:
    """Opt-in debug mode: ``POND_DEBUG_INVARIANTS=1`` in the
    environment makes the streaming engines verify the packed carry
    and the event tensors after every shard (host round-trip per
    shard — debug cost, never on by default)."""
    return os.environ.get("POND_DEBUG_INVARIANTS", "") == "1"


def check_invariants(fc, um, up, *, n_servers: int,
                     cores_per_server: float, shard: int,
                     up_slack: float = 0.0) -> None:
    """Verify the packed carry after a shard (any backend's layout:
    ``(C, S)``/``(C, G)`` or batched ``(K, C, S)``/``(K, C, G)``).

    Checks, on the real server columns: free cores within
    ``[0, cores_per_server]`` (capacity conservation per server — used
    cores never negative, never above capacity), used local memory
    non-negative, used pool above ``-up_slack`` (the documented
    fallback-migrate deficit bound) and every entry finite.  Raises
    :class:`SweepInvariantError` naming the shard and the first
    offending (trace,) lane.
    """
    fc = np.asarray(fc, np.float64)[..., :n_servers]
    um = np.asarray(um, np.float64)[..., :n_servers]
    up = np.asarray(up, np.float64)

    def _raise(what, lane_mask, detail=""):
        first = np.argwhere(lane_mask)[0]
        trace = int(first[0]) if lane_mask.ndim == 2 else None
        lane = int(first[-1])
        raise SweepInvariantError(what, shard=shard, lane=lane,
                                  trace=trace, detail=detail)

    for name, a in (("free-cores", fc), ("used-local-GB", um),
                    ("used-pool-GB", up)):
        bad = ~np.isfinite(a)
        if bad.any():
            _raise(f"non-finite {name}", bad.any(-1))
    bad = (fc < 0) | (fc > cores_per_server)
    if bad.any():
        _raise("free cores outside [0, cores_per_server]", bad.any(-1),
               f"range [{fc.min()}, {fc.max()}]")
    if (um < 0).any():
        _raise("negative used local memory", (um < 0).any(-1),
               f"min {um.min()}")
    if (up < -up_slack - 1e-9).any():
        _raise("used pool below the migrate-deficit bound",
               (up < -up_slack - 1e-9).any(-1),
               f"min {up.min()} < -{up_slack}")


def check_event_tensors(shard: dict, shard_idx: int,
                        n_slots: int) -> None:
    """Verify one shard's event tensors (finite, kinds/slots/payloads
    in domain) under the invariant guard; ``lane`` in the raised error
    is the offending EVENT index within the shard."""
    def _raise(what, mask):
        raise SweepInvariantError(what, shard=shard_idx,
                                  lane=int(np.argwhere(mask)[0][-1]))

    kind = np.asarray(shard["kind"])
    bad = (kind < ARRIVE) | (kind > RECOVER)
    if bad.any():
        _raise("event kind out of range", bad)
    slot = np.asarray(shard["slot"])
    bad = (slot < 0) | (slot >= n_slots)
    if bad.any():
        _raise("event slot out of range", bad)
    for key in ("c", "l", "p", "m"):
        if key not in shard:
            continue
        a = np.asarray(shard[key], np.float64)
        if not np.isfinite(a).all():
            _raise(f"non-finite event payload {key!r}", ~np.isfinite(a))
        vm_ev = (kind == ARRIVE) | (kind == DEPART) | (kind == MIGRATE)
        if (vm_ev & (a < 0)).any():
            _raise(f"negative event payload {key!r}", vm_ev & (a < 0))


# ------------------------------------------------------------- state rules --
def state_np_dtype(state_dtype: str):
    """Host numpy dtype of the packed sweep state."""
    return np.int16 if state_dtype == "int16" else np.int32


def state_sentinel(state_dtype: str) -> int:
    """Best-fit score sentinel / "infinite" magnitude for the dtype."""
    return I16_BIG if state_dtype == "int16" else I32_BIG


def pick_state_dtype(cores_per_server: float, n_servers: int,
                     sgb_i: np.ndarray, pgb_i: np.ndarray,
                     pay_mem_max: float, pay_pool_max: float,
                     mig_pool_sum: float = 0.0) -> str:
    """``"int16"`` when every sweep intermediate provably fits int16.

    The admission tests compute at most ``capacity + one payload``
    (used mem is invariantly <= server_gb, used pool <= pool_gb), so
    int16 is bit-equivalent to int32 whenever the candidate maxima
    plus the per-VM payload maxima stay within :data:`I16_SAFE`, the
    best-fit score sentinel exceeds every free-cores value, and the
    packed slot values (server * 2 + 1) fit.  MIGRATE-bearing traces
    need one more bound: the oracle's fallback-migrate quirk returns
    pool a fallback-placed VM never consumed, driving the used-pool
    carry NEGATIVE — by at most the pool payload of each compiled
    MIGRATE event, so the total compiled migrate-event pool
    (``mig_pool_sum``) bounds the deficit.  When that sum plus the
    payload headroom fits :data:`I16_SAFE` too, migrate traces pack to
    int16 like any other; anything else falls back to int32
    automatically.
    """
    if (cores_per_server < I16_BIG
            and n_servers * 2 + 1 < I16_BIG
            and len(sgb_i) and sgb_i.min() >= 0 and pgb_i.min() >= 0
            and sgb_i.max() + pay_mem_max <= I16_SAFE
            and pgb_i.max() + pay_pool_max <= I16_SAFE
            and mig_pool_sum + pay_pool_max <= I16_SAFE):
        return "int16"
    return "int32"


def quantize_capacities(server_gb, pool_gb):
    """Floor + clip candidate capacities to the int sweep's domain.

    Integral quantities: flooring keeps every admission test identical
    to the float64 oracle; ±2^30 stands in for "infinite" probes.
    """
    sgb_i = np.clip(np.floor(server_gb), -I32_BIG, I32_BIG)
    pgb_i = np.clip(np.floor(pool_gb), -I32_BIG, I32_BIG)
    return sgb_i, pgb_i


# ---------------------------------------------------------------- padding --
def pad_up(n: int, granularity: int, minimum: int | None = None) -> int:
    """``n`` rounded up to a multiple of ``granularity`` (>= minimum)."""
    m = granularity if minimum is None else minimum
    return max(m, (n + granularity - 1) // granularity * granularity)


def bucket_width(k: int) -> int:
    """Padded candidate width for a k-candidate chunk (fixed buckets keep
    XLA recompiles rare; small buckets matter for narrow probe batches)."""
    for b in BUCKETS:
        if k <= b:
            return b
    return BUCKETS[-1]


def candidate_chunks(n: int):
    """Yield ``(lo, hi, width)`` candidate chunks of at most JAX_CHUNK,
    each padded to its bucket width.

    With tracing on, every chunk feeds the ``pad.cand_lanes_used`` /
    ``pad.cand_lanes_padded`` counters — the padding-waste ratio of the
    bucket scheme over the run's actual candidate batches.
    """
    rec = obs.get_recorder()
    for lo in range(0, n, JAX_CHUNK):
        hi = min(lo + JAX_CHUNK, n)
        width = bucket_width(hi - lo)
        if rec.enabled:
            rec.count("pad.cand_lanes_used", hi - lo)
            rec.count("pad.cand_lanes_padded", width - (hi - lo))
        yield lo, hi, width


def lane_capacities(sgb_i: np.ndarray, pgb_i: np.ndarray, lo: int,
                    hi: int, width: int, np_dt) -> tuple:
    """Candidate capacities for one chunk, padded to ``width`` lanes.

    Padding lanes replicate the chunk's last candidate (their results
    are discarded), so padded lanes never hit a different control-flow
    path.  Accepts 1-D ``(n,)`` (single trace) or 2-D ``(K, n)``
    (per-trace candidate grids) arrays.
    """
    if sgb_i.ndim == 1:
        sgb = np.full(width, sgb_i[hi - 1], np_dt)
        pgb = np.full(width, pgb_i[hi - 1], np_dt)
        sgb[:hi - lo] = sgb_i[lo:hi]
        pgb[:hi - lo] = pgb_i[lo:hi]
    else:
        sgb = np.repeat(sgb_i[:, hi - 1:hi], width, 1).astype(np_dt)
        pgb = np.repeat(pgb_i[:, hi - 1:hi], width, 1).astype(np_dt)
        sgb[:, :hi - lo] = sgb_i[:, lo:hi]
        pgb[:, :hi - lo] = pgb_i[:, lo:hi]
    return sgb, pgb


# ---------------------------------------------------- carry pack / unpack --
def init_state(width: int, n_servers: int, cores_per_server: float,
               s_pad: int, g_pad: int, n_slots: int, np_dt,
               k: int | None = None) -> tuple:
    """Packed all-free initial sweep state, as host numpy arrays.

    Returns ``(fc0, um0, up0, slots0, rej0)``: free cores per (lane,
    server) — padded server columns pinned to the negative sentinel so
    they never win a best-fit — used local GB, used pool GB per (lane,
    group), the slot array (-1 = empty) and the int32 reject counters.
    With ``k`` set, every array gains a leading trace axis (the
    per-trace carry of the batched streaming sweep).  Callers place the
    arrays with :func:`device_put`; the carry variants then donate them
    back to the sweep so the state stays device-resident.
    """
    neg = state_sentinel(
        "int16" if np_dt == np.int16 else "int32")
    fc0 = np.full((width, s_pad), -neg, np_dt)
    fc0[:, :n_servers] = np_dt(cores_per_server)
    um0 = np.zeros((width, s_pad), np_dt)
    up0 = np.zeros((width, g_pad), np_dt)
    slots0 = np.full((n_slots, width), -1, np_dt)
    rej0 = np.zeros(width, np.int32)
    if k is None:
        return fc0, um0, up0, slots0, rej0
    return tuple(np.broadcast_to(a, (k,) + a.shape).copy()
                 for a in (fc0, um0, up0, slots0, rej0))


def assign_slots(ev_kind, ev_vm, n_vms: int) -> tuple:
    """Map each event's VM to a reusable placement slot.

    Slots free on departure, so the per-candidate placement state is
    sized by PEAK CONCURRENCY rather than trace length.  Returns the
    per-event slot array and the raw slot count (pad with
    :func:`pad_up` / :data:`SLOT_PAD`).
    """
    slot_of = np.zeros(n_vms, np.int64)
    ev_slot = np.zeros(len(ev_kind), np.int64)
    free_slots: list[int] = []
    next_slot = 0
    for e in range(len(ev_kind)):
        v = ev_vm[e]
        kind = ev_kind[e]
        if kind == ARRIVE:
            if free_slots:
                slot_of[v] = free_slots.pop()
            else:
                slot_of[v] = next_slot
                next_slot += 1
        ev_slot[e] = slot_of[v]
        if kind == DEPART:
            free_slots.append(int(slot_of[v]))
    return ev_slot, next_slot


# -------------------------------------------------------------- placement --
def device_put(x, sharding=None):
    """Place a host array on jax's default device, explicitly.

    One shared entry point so every engine uploads event shards and
    carry state the same way: on CPU this is a no-copy wrap, on
    GPU/TPU an explicit host->device transfer — which, combined with
    the donated carry args of the carry sweeps, keeps the packed state
    device-resident across shards and peak device memory bounded by
    one shard (batch) plus the carry.

    ``sharding`` (a :func:`named_sharding`) places the array across a
    device mesh instead — sliced along the spec'd axis or replicated —
    so sharded sweeps receive inputs already laid out the way their
    ``shard_map`` expects (no resharding transfer inside the jit).

    With tracing on, the transfer volume feeds ``device_put.calls`` /
    ``device_put.bytes`` (host-side nbytes of the placed array).
    """
    import jax
    rec = obs.get_recorder()
    if rec.enabled:
        rec.count("device_put.calls")
        rec.count("device_put.bytes", int(getattr(x, "nbytes", 0)))
    if sharding is None:
        return jax.device_put(x)
    return jax.device_put(x, sharding)


# --------------------------------------------------------------- sharding --
_MESHES: dict = {}     # device-id tuple -> cached 1-D "shard"-axis Mesh


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions (the single mesh shim —
    ``launch/mesh.py`` re-exports it): ``AxisType`` only exists on
    jax >= 0.5 (where Auto is the default anyway).  ``devices`` narrows
    the mesh to an explicit device list (default: all visible)."""
    import jax
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes), **kw)
    return jax.make_mesh(shape, axes, **kw)


def resolve_devices(devices):
    """Normalize an engine ``devices=`` argument to a device list.

    ``None`` -> no sharding; ``"all"`` -> every visible jax device;
    an int -> the first n visible devices; a sequence of jax devices
    passes through.  Fewer than 2 resolved devices degrades to
    ``None`` (the single-device path), so ``devices="all"`` is safe on
    any host — on CPU-only machines, force a device pool with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    if devices is None or not jax_importable():
        return None
    import jax
    if isinstance(devices, str):
        if devices != "all":
            raise ValueError(
                f"devices={devices!r}: expected 'all', an int, a "
                "device sequence, or None")
        devs = list(jax.devices())
    elif isinstance(devices, int):
        devs = list(jax.devices())[:devices]
    else:
        devs = list(devices)
    return devs if len(devs) >= 2 else None


def shard_mesh(devs):
    """Cached 1-D mesh over ``devs`` with a single ``"shard"`` axis —
    the only mesh shape the sweep sharding uses (the batch axes being
    partitioned are 1-D)."""
    key = tuple(d.id for d in devs)
    mesh = _MESHES.get(key)
    if mesh is None:
        mesh = make_mesh((len(devs),), ("shard",), devices=devs)
        _MESHES[key] = mesh
    return mesh


def lane_shard_count(width: int, n_devices: int) -> int:
    """Largest device count <= ``n_devices`` evenly dividing a lane
    bucket — the lane axis must split evenly across the mesh."""
    n = max(1, min(n_devices, width))
    while width % n:
        n -= 1
    return n


def named_sharding(mesh, *spec):
    """``NamedSharding(mesh, PartitionSpec(*spec))`` — e.g.
    ``named_sharding(mesh, "shard")`` slices dim 0 across the mesh,
    ``named_sharding(mesh)`` replicates, ``named_sharding(mesh, None,
    "shard")`` slices dim 1."""
    import jax
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(*spec))


def _mesh_key(mesh):
    return tuple(d.id for d in mesh.devices.flat)


def _plain_shard_specs(P, with_carry: bool, batched: bool, axis: str):
    """``shard_map`` (in_specs, out_specs) for the plain sweep family.

    ``axis="trace"`` partitions the leading K axis of the event rows,
    candidate capacities and (carry variants) every state array; the
    shared-init batched variant keeps its trace-free initial state
    replicated.  ``axis="lane"`` replicates the event stream and
    splits the candidate-lane axis of the state — dim 0 of the lane
    arrays (dim 1 after a leading trace axis), dim 1 of the
    ``(n_slots, W)`` slot array (dim 2 batched).  Either way the
    sharded rows/lanes replay independently (the best-fit argmin runs
    over the never-sharded server axis), so results are bit-exact.
    """
    S, R = P("shard"), P()
    if axis == "trace":
        if not batched:
            raise ValueError("trace sharding requires batched=True")
        ev = (S,) * 6
        if with_carry:
            return (ev, R, S, S, S, S, S, S, S), (S, S, S, S, S)
        return (ev, R, R, R, R, R, S, S), S
    if axis != "lane":
        raise ValueError(f"unknown shard axis {axis!r}")
    ev = (R,) * 6
    if not batched:
        L, Ls = S, P(None, "shard")
        if with_carry:
            return (ev, R, L, L, L, Ls, L, L, L), (L, L, L, Ls, L)
        return (ev, R, L, L, L, Ls, L, L), L
    L, Ls = P(None, "shard"), P(None, None, "shard")
    if with_carry:
        return (ev, R, L, L, L, Ls, L, L, L), (L, L, L, Ls, L)
    # shared-init batched: the initial state has NO trace axis
    return (ev, R, S, S, S, P(None, "shard"), L, L), L


def _pod_shard_specs(P, with_carry: bool):
    """``shard_map`` specs for the batched pod sweeps, trace axis only
    (the incidence tensor stays replicated across devices)."""
    S, R = P("shard"), P()
    ev = (S,) * 6
    if with_carry:
        return (ev, R, S, S, S, S, S, S, S, S), (S, S, S, S, S, S)
    return (ev, R, R, R, R, R, R, S, S), S
