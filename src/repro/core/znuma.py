"""zNUMA: zero-core tier placement (Pond §4.2, Figure 10).

Pond exposes pool memory to the guest as a NUMA node with memory but no
cores; the guest allocator then *biases* all hot traffic to the local node
and only spills into the zNUMA node when local is exhausted.  Pond-JAX's
analogue (DESIGN.md §2):

  * every logical buffer group (params / grads / optimizer state / KV
    blocks) carries a tier tag, ``local`` (chip HBM) or ``pool`` (host
    memory behind the chip group);
  * ``tier_shardings`` rewrites NamedShardings with
    ``memory_kind="pinned_host"`` for pool-tier leaves — the TPU path where
    XLA emits async device<->host copies (ld/st-like, no page faults);
    on backends without host memory-space support (this CPU container) the
    placement is recorded by the accounting below and exercised by the
    two-phase optimizer split;
  * ``ZNumaAllocator`` reproduces the guest-allocator bias for block pools:
    allocate local-first, spill to pool, and track the spill fraction —
    the quantity Figure 16 sweeps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np


def supports_host_memory_kind() -> bool:
    """True when the backend accepts pinned_host shardings in compiles."""
    return jax.default_backend() in ("tpu", "gpu")


def tier_shardings(shardings, tiers, default: str = "local"):
    """Rewrite a NamedSharding tree with memory kinds per tier tag.

    ``tiers`` is either a str ("pool") applied to the whole tree or a dict
    keyed by top-level group name (e.g. optim.adamw.state_tier()).
    """
    if not supports_host_memory_kind():
        return shardings

    def kind_for(group):
        t = tiers if isinstance(tiers, str) else tiers.get(group, default)
        return "pinned_host" if t == "pool" else "device"

    if isinstance(tiers, str):
        return jax.tree.map(
            lambda s: s.with_memory_kind(kind_for(None)), shardings)
    out = {}
    for group, sub in shardings.items():
        out[group] = jax.tree.map(
            lambda s, g=group: s.with_memory_kind(kind_for(g)), sub)
    return out


@dataclasses.dataclass
class TierAccount:
    """Byte accounting per tier — what memory_analysis would show on TPU."""
    local_bytes: int = 0
    pool_bytes: int = 0

    def add(self, tree, tier: str):
        n = sum(x.size * x.dtype.itemsize if hasattr(x, "dtype")
                else 0 for x in jax.tree.leaves(tree))
        if tier == "pool":
            self.pool_bytes += n
        else:
            self.local_bytes += n
        return self

    @property
    def pool_fraction(self) -> float:
        tot = self.local_bytes + self.pool_bytes
        return self.pool_bytes / tot if tot else 0.0


class ZNumaAllocator:
    """Local-first block allocator over a two-tier pool (guest-OS bias).

    Used by serving/kv_cache.py: ``num_local`` blocks of HBM plus
    ``num_pool`` blocks on the slice pool.  Allocation order reproduces the
    zNUMA bias: pool blocks are touched only after local is exhausted, so a
    correctly-sized local tier (= predicted hot footprint) never spills.
    """

    def __init__(self, num_local: int, num_pool: int):
        self.num_local = num_local
        self.num_pool = num_pool
        self.free_local = list(range(num_local - 1, -1, -1))
        self.free_pool = list(range(num_local + num_pool - 1,
                                    num_local - 1, -1))
        self.allocs = 0
        self.pool_allocs = 0

    def alloc(self) -> int:
        """Returns a global block id; local ids < num_local.

        Only SUCCESSFUL allocations count toward ``allocs``: the seed
        code incremented before checking the free lists, so a failed
        (MemoryError) allocation deflated ``spill_fraction`` — the
        quantity Fig 16 sweeps (regression pinned in
        tests/test_latency_engine.py).
        """
        if self.free_local:
            self.allocs += 1
            return self.free_local.pop()
        if self.free_pool:
            self.allocs += 1
            self.pool_allocs += 1
            return self.free_pool.pop()
        raise MemoryError("zNUMA: both tiers exhausted")

    def free(self, block_id: int):
        if block_id < self.num_local:
            self.free_local.append(block_id)
        else:
            self.free_pool.append(block_id)

    def is_pool(self, block_id: int) -> bool:
        return block_id >= self.num_local

    @property
    def spill_fraction(self) -> float:
        return self.pool_allocs / self.allocs if self.allocs else 0.0

    @property
    def local_in_use(self) -> int:
        return self.num_local - len(self.free_local)

    @property
    def pool_in_use(self) -> int:
        return self.num_pool - len(self.free_pool)
