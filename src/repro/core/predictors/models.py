"""Pond's two prediction models (§4.4, Figures 12-14).

LatencySensitivityModel  — RandomForest over core-PMU/TMA counters;
  classify "latency insensitive" = running fully on pool memory keeps the
  slowdown within the PDM.  Parameterized by a probability threshold;
  sweeping it yields the Figure-17 (LI%, FP%) tradeoff curve.  Includes the
  paper's two heuristic baselines ("Memory bound" / "DRAM bound"
  single-counter thresholds).

UntouchedMemoryModel — quantile GBM over VM metadata (customer history
  percentiles are the strongest feature, §4.4); sweeping the target
  quantile yields the Figure-18 (UM%, OP%) curve, against the static
  fixed-fraction strawman.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.qos import exceeds_pdm
from repro.core.predictors.forest import RandomForest, fit_forest
from repro.core.predictors.gbm import QuantileGBM, fit_gbm


@dataclasses.dataclass
class LICurvePoint:
    threshold: float
    li_frac: float         # fraction of workloads labeled insensitive
    fp_frac: float         # sensitive-but-labeled-insensitive / total


class LatencySensitivityModel:
    def __init__(self, pdm: float = 0.05):
        self.pdm = pdm
        self.forest: RandomForest | None = None

    def fit(self, pmu_features: np.ndarray, slowdowns: np.ndarray,
            seed: int = 0):
        """slowdowns: relative (0.03 = 3%).  Label 1 = sensitive."""
        y = exceeds_pdm(slowdowns, self.pdm).astype(np.float32)
        self.forest = fit_forest(pmu_features, y, seed=seed)
        return self

    def p_sensitive(self, pmu_features: np.ndarray) -> np.ndarray:
        return self.forest.predict_proba(pmu_features)

    def p_sensitive_batch(self, pmu_features: np.ndarray) -> np.ndarray:
        """Whole-trace probabilities whose row ``i`` bit-matches the
        control plane's per-VM ``p_sensitive(pmu[None])[0]`` call (see
        ``RandomForest.predict_proba_batch``); the compiled policy
        engine scores every VM in one call through this path."""
        return self.forest.predict_proba_batch(pmu_features)

    def insensitive(self, pmu_features: np.ndarray,
                    threshold: float) -> np.ndarray:
        return self.p_sensitive(pmu_features) < threshold

    def curve(self, pmu_features, slowdowns, thresholds=None):
        """Figure 17: (LI, FP) as the threshold sweeps."""
        sens = exceeds_pdm(slowdowns, self.pdm)
        p = self.p_sensitive(pmu_features)
        pts = []
        ths = thresholds if thresholds is not None \
            else np.unique(np.round(np.linspace(0.0, 1.0, 101), 3))
        for t in ths:
            li = p < t
            pts.append(LICurvePoint(float(t), float(li.mean()),
                                    float((li & sens).mean())))
        return pts

    def threshold_for_fp(self, pmu_features, slowdowns,
                         fp_target: float) -> LICurvePoint:
        """Largest-LI point with FP <= target (the paper's FP knob)."""
        best = LICurvePoint(0.0, 0.0, 0.0)
        for pt in self.curve(pmu_features, slowdowns):
            if pt.fp_frac <= fp_target and pt.li_frac >= best.li_frac:
                best = pt
        return best


def heuristic_curve(counter: np.ndarray, slowdowns: np.ndarray,
                    pdm: float = 0.05):
    """Single-counter threshold baselines (Fig 17: Memory/DRAM bound)."""
    sens = exceeds_pdm(slowdowns, pdm)
    pts = []
    for t in np.quantile(counter, np.linspace(0, 1, 101)):
        li = counter < t
        pts.append(LICurvePoint(float(t), float(li.mean()),
                                float((li & sens).mean())))
    return pts


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class UMCurvePoint:
    tau: float
    um_frac: float          # mean predicted untouched fraction (of memory)
    op_frac: float          # fraction of VMs with actual < predicted


class UntouchedMemoryModel:
    """Quantile regression of the minimum untouched fraction over a VM's
    lifetime, from metadata features."""

    def __init__(self, tau: float = 0.2):
        self.tau = tau
        self.gbm: QuantileGBM | None = None

    def fit(self, meta_features: np.ndarray, untouched_frac: np.ndarray,
            seed: int = 0):
        self.gbm = fit_gbm(meta_features, untouched_frac, tau=self.tau,
                           seed=seed)
        return self

    def predict(self, meta_features: np.ndarray) -> np.ndarray:
        """GB-alignment: predictions are rounded DOWN to the slice grain by
        the control plane, never up (§4.4)."""
        return np.clip(self.gbm.predict(meta_features), 0.0, 1.0)

    @staticmethod
    def curve(meta_features, untouched, taus=None, seed: int = 0):
        """Figure 18: (UM, OP) sweeping the target quantile."""
        pts = []
        for tau in (taus if taus is not None
                    else (0.02, 0.05, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5)):
            m = UntouchedMemoryModel(tau).fit(meta_features, untouched,
                                              seed=seed)
            pred = m.predict(meta_features)
            pts.append(UMCurvePoint(tau, float(pred.mean()),
                                    float((untouched < pred).mean())))
        return pts

    @staticmethod
    def static_curve(untouched, fracs=None):
        """Strawman: same fixed untouched fraction for every VM."""
        pts = []
        for f in (fracs if fracs is not None
                  else np.linspace(0.0, 0.6, 25)):
            pts.append(UMCurvePoint(float(f), float(f),
                                    float((untouched < f).mean())))
        return pts
