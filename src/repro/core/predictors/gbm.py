"""Gradient-boosted trees with quantile (pinball) loss — Pond's
untouched-memory model core (§5: LightGBM quantile regression, rebuilt
from scratch).

Each stage fits a CART to the pinball-loss negative gradient
(tau - 1[y < F]) and then replaces leaf values with the tau-quantile of
the residuals inside the leaf (the exact line-search for pinball loss).
A lower tau gives a more conservative (under-)prediction of untouched
memory -> fewer overpredictions (OP), less pool usage (UM): the knob the
Eq.(1) combiner sweeps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.predictors import trees as T


@dataclasses.dataclass
class QuantileGBM:
    f0: float
    stages: list
    lr: float
    tau: float

    def predict(self, x: np.ndarray) -> np.ndarray:
        out = np.full(len(x), self.f0, np.float32)
        for t in self.stages:
            out += self.lr * t.predict(x)
        return out


def fit_gbm(x: np.ndarray, y: np.ndarray, tau: float = 0.2,
            n_stages: int = 60, lr: float = 0.15, max_depth: int = 4,
            min_leaf: int = 16, seed: int = 0) -> QuantileGBM:
    rng = np.random.default_rng(seed)
    f = np.full(len(y), np.quantile(y, tau), np.float32)
    f0 = float(f[0])
    stages = []
    for s in range(n_stages):
        grad = np.where(y < f, tau - 1.0, tau).astype(np.float32)
        tree = T.fit_tree(x, grad, max_depth=max_depth, min_leaf=min_leaf,
                          rng=np.random.default_rng(seed + s))
        # exact leaf line-search: tau-quantile of residual within each leaf
        leaves = tree.leaf_index(x)
        resid = y - f
        new_vals = tree.value.copy()
        for leaf in np.unique(leaves):
            r = resid[leaves == leaf]
            if len(r):
                new_vals[leaf] = np.quantile(r, tau)
        tree.value[:] = new_vals
        f = f + lr * tree.predict(x)
        stages.append(tree)
    return QuantileGBM(f0, stages, lr, tau)
