"""Gradient-boosted trees with quantile (pinball) loss — Pond's
untouched-memory model core (§5: LightGBM quantile regression, rebuilt
from scratch).

Each stage fits a CART to the pinball-loss negative gradient
(tau - 1[y < F]) and then replaces leaf values with the tau-quantile of
the residuals inside the leaf (the exact line-search for pinball loss).
A lower tau gives a more conservative (under-)prediction of untouched
memory -> fewer overpredictions (OP), less pool usage (UM): the knob the
Eq.(1) combiner sweeps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.predictors import trees as T


@dataclasses.dataclass
class QuantileGBM:
    f0: float
    stages: list
    lr: float
    tau: float
    packed: dict | None = None

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Batched prediction.  Row ``i`` is bit-identical to predicting
        row ``i`` alone: every stage's tree walk is an elementwise
        gather and the ``+=`` accumulates stage by stage in the same
        float32 order for any batch size (the property the compiled
        policy engine's one-call inference relies on)."""
        out = np.full(len(x), self.f0, np.float32)
        for t in self.stages:
            out += self.lr * t.predict(x)
        return out

    def predict_jax(self, x):
        """XLA inference over the packed stage stack (float32; matches
        :meth:`predict` to ensemble rounding, not bitwise)."""
        import jax.numpy as jnp
        if self.packed is None:
            self.packed = T.pack_trees(self.stages)
        preds = T.predict_stack_jax(self.packed, jnp.asarray(x))
        return self.f0 + self.lr * jnp.sum(preds, axis=0)


def pack_gbms(models: "list[QuantileGBM]") -> dict:
    """Stack several fitted GBMs into one padded array pytree.

    Pads every model's stages to a common (n_stages, n_nodes) shape —
    padding stages are single-leaf zero-value trees, so they contribute
    ``lr * 0`` — and stacks to ``(G, S, n)`` arrays plus per-model
    ``f0``/``lr`` vectors.  The result feeds :func:`predict_gbms_jax`,
    which vmaps ONE evaluation over the model axis: this is how the
    policy engine prices a whole tau grid against a trace batch in a
    single compiled call (see ``core/policy_engine.py``).
    """
    import jax.numpy as jnp
    per = [T.pack_trees(m.stages) for m in models]
    s_max = max(p["feature"].shape[0] for p in per)
    n_max = max(p["feature"].shape[1] for p in per)

    def pad(p, key, fill):
        a = np.asarray(p[key])
        out = np.full((s_max, n_max), fill, a.dtype)
        out[:a.shape[0], :a.shape[1]] = a
        return out

    packed = {key: jnp.asarray(np.stack([pad(p, key, fill) for p in per]))
              for key, fill in (("feature", -1), ("threshold", 0.0),
                                ("left", 0), ("right", 0), ("value", 0.0))}
    packed["depth"] = max(p["depth"] for p in per)
    packed["f0"] = jnp.asarray(np.array([m.f0 for m in models],
                                        np.float32))
    packed["lr"] = jnp.asarray(np.array([m.lr for m in models],
                                        np.float32))
    return packed


def predict_gbms_jax(packed, x):
    """All models of a :func:`pack_gbms` stack on one batch: (G, B).

    A single vmap over the model axis — G tau settings price a trace
    batch in one XLA call instead of G numpy ensemble walks.
    """
    import jax
    import jax.numpy as jnp
    xb = jnp.asarray(x)

    def one_model(feat, thr, left, right, value, f0, lr):
        preds = T.predict_stack_jax(
            {"feature": feat, "threshold": thr, "left": left,
             "right": right, "value": value,
             "depth": packed["depth"]}, xb)
        return f0 + lr * jnp.sum(preds, axis=0)

    return jax.vmap(one_model)(packed["feature"], packed["threshold"],
                               packed["left"], packed["right"],
                               packed["value"], packed["f0"],
                               packed["lr"])


def fit_gbm(x: np.ndarray, y: np.ndarray, tau: float = 0.2,
            n_stages: int = 60, lr: float = 0.15, max_depth: int = 4,
            min_leaf: int = 16, seed: int = 0) -> QuantileGBM:
    rng = np.random.default_rng(seed)
    f = np.full(len(y), np.quantile(y, tau), np.float32)
    f0 = float(f[0])
    stages = []
    for s in range(n_stages):
        grad = np.where(y < f, tau - 1.0, tau).astype(np.float32)
        tree = T.fit_tree(x, grad, max_depth=max_depth, min_leaf=min_leaf,
                          rng=np.random.default_rng(seed + s))
        # exact leaf line-search: tau-quantile of residual within each leaf
        leaves = tree.leaf_index(x)
        resid = y - f
        new_vals = tree.value.copy()
        for leaf in np.unique(leaves):
            r = resid[leaves == leaf]
            if len(r):
                new_vals[leaf] = np.quantile(r, tau)
        tree.value[:] = new_vals
        f = f + lr * tree.predict(x)
        stages.append(tree)
    return QuantileGBM(f0, stages, lr, tau)
