"""CART decision trees: numpy fit, array-form vectorized inference.

Trees are stored as flat arrays (feature, threshold, left, right, value,
is_leaf) so inference is a fixed-depth gather loop — vectorizable in numpy
and jit/vmap-able in JAX (predict_jax).  This is the substrate for Pond's
two models: the RandomForest latency-insensitivity classifier and the
quantile-GBM untouched-memory regressor (§4.4/§5 — sklearn/LightGBM in the
paper, reimplemented here from scratch).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Tree:
    feature: np.ndarray     # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray   # (n_nodes,) float32
    left: np.ndarray        # (n_nodes,) int32
    right: np.ndarray       # (n_nodes,) int32
    value: np.ndarray       # (n_nodes,) float32 (leaf prediction)
    depth: int

    def predict(self, x: np.ndarray) -> np.ndarray:
        idx = np.zeros(len(x), np.int32)
        for _ in range(self.depth + 1):
            f = self.feature[idx]
            leaf = f < 0
            go_left = np.where(
                leaf, True,
                x[np.arange(len(x)), np.maximum(f, 0)] <= self.threshold[idx])
            nxt = np.where(go_left, self.left[idx], self.right[idx])
            idx = np.where(leaf, idx, nxt)
        return self.value[idx]

    def leaf_index(self, x: np.ndarray) -> np.ndarray:
        idx = np.zeros(len(x), np.int32)
        for _ in range(self.depth + 1):
            f = self.feature[idx]
            leaf = f < 0
            go_left = np.where(
                leaf, True,
                x[np.arange(len(x)), np.maximum(f, 0)] <= self.threshold[idx])
            nxt = np.where(go_left, self.left[idx], self.right[idx])
            idx = np.where(leaf, idx, nxt)
        return idx


def _best_split(x, y, feat_ids, min_leaf, n_thresholds=16, rng=None):
    """Greedy variance-reduction split over candidate quantile thresholds."""
    n = len(y)
    best = (None, None, np.inf)
    parent = np.var(y) * n
    for f in feat_ids:
        xv = x[:, f]
        qs = np.unique(np.quantile(
            xv, np.linspace(0.05, 0.95, n_thresholds)))
        for t in qs:
            mask = xv <= t
            nl = int(mask.sum())
            if nl < min_leaf or n - nl < min_leaf:
                continue
            yl, yr = y[mask], y[~mask]
            score = np.var(yl) * nl + np.var(yr) * (n - nl)
            if score < best[2]:
                best = (f, t, score)
    if best[0] is None or best[2] >= parent - 1e-12:
        return None
    return best[0], best[1]


def fit_tree(x: np.ndarray, y: np.ndarray, max_depth: int = 6,
             min_leaf: int = 8, max_features: int | None = None,
             rng: np.random.Generator | None = None) -> Tree:
    rng = rng or np.random.default_rng(0)
    nodes = {"feature": [], "threshold": [], "left": [], "right": [],
             "value": []}

    def new_node():
        for k in nodes:
            nodes[k].append(0 if k != "feature" else -1)
        return len(nodes["feature"]) - 1

    def build(idx_samples, depth):
        nid = new_node()
        ys = y[idx_samples]
        nodes["value"][nid] = float(np.mean(ys)) if len(ys) else 0.0
        if depth >= max_depth or len(idx_samples) < 2 * min_leaf \
                or np.all(ys == ys[0]):
            return nid
        nfeat = x.shape[1]
        feats = (rng.choice(nfeat, size=min(max_features or nfeat, nfeat),
                            replace=False))
        sp = _best_split(x[idx_samples], ys, feats, min_leaf)
        if sp is None:
            return nid
        f, t = sp
        mask = x[idx_samples, f] <= t
        nodes["feature"][nid] = int(f)
        nodes["threshold"][nid] = float(t)
        nodes["left"][nid] = build(idx_samples[mask], depth + 1)
        nodes["right"][nid] = build(idx_samples[~mask], depth + 1)
        return nid

    build(np.arange(len(y)), 0)
    return Tree(np.array(nodes["feature"], np.int32),
                np.array(nodes["threshold"], np.float32),
                np.array(nodes["left"], np.int32),
                np.array(nodes["right"], np.int32),
                np.array(nodes["value"], np.float32),
                max_depth)


# ------------------------------------------------------------ JAX predict --
def pack_trees(trees: list[Tree]):
    """Pad trees to equal node count -> stacked arrays for vmap inference."""
    n = max(len(t.feature) for t in trees)

    def pad(a, fill):
        return np.stack([np.pad(getattr(t, a), (0, n - len(t.feature)),
                                constant_values=fill) for t in trees])
    return {"feature": jnp.asarray(pad("feature", -1)),
            "threshold": jnp.asarray(pad("threshold", 0.0)),
            "left": jnp.asarray(pad("left", 0)),
            "right": jnp.asarray(pad("right", 0)),
            "value": jnp.asarray(pad("value", 0.0)),
            "depth": max(t.depth for t in trees)}


def predict_stack_jax(packed, x: jax.Array) -> jax.Array:
    """Per-tree predictions of a packed ensemble: x (B, F) -> (T, B).

    The shared substrate for ensemble reductions: the RandomForest mean
    (``predict_jax``), the GBM's ``f0 + lr * sum`` (``gbm.predict_jax``)
    and the vmapped multi-model grid path (``gbm.predict_gbms_jax``).
    """
    depth = packed["depth"]

    def one_tree(feat, thr, left, right, value):
        def step(_, idx):
            f = feat[idx]
            leaf = f < 0
            xv = x[jnp.arange(x.shape[0]), jnp.maximum(f, 0)]
            nxt = jnp.where(xv <= thr[idx], left[idx], right[idx])
            return jnp.where(leaf, idx, nxt)
        idx = jax.lax.fori_loop(0, depth + 1, step,
                                jnp.zeros(x.shape[0], jnp.int32))
        return value[idx]

    return jax.vmap(one_tree)(packed["feature"], packed["threshold"],
                              packed["left"], packed["right"],
                              packed["value"])


def predict_jax(packed, x: jax.Array) -> jax.Array:
    """Ensemble mean prediction.  x: (B, F) -> (B,).  jit-able."""
    return jnp.mean(predict_stack_jax(packed, x), axis=0)


def predict_stack(trees: list[Tree], x: np.ndarray) -> np.ndarray:
    """numpy pendant of :func:`predict_stack_jax`: (T, B) per-tree
    predictions.  Each tree's gather loop is elementwise per row, so
    row ``i`` of the stack is bit-identical to predicting row ``i``
    alone — the property the compiled policy engine's batched
    inference relies on."""
    return np.stack([t.predict(x) for t in trees])
