"""RandomForest classifier — Pond's latency-insensitivity model core (§5).

Bootstrap + per-split feature subsampling over trees.py CART; predicted
probability = ensemble mean of leaf class fractions.  Inference available
in numpy and packed-JAX form.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.predictors import trees as T


@dataclasses.dataclass
class RandomForest:
    trees: list
    packed: dict | None = None

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(x) for t in self.trees], axis=0)

    def predict_proba_jax(self, x):
        import jax.numpy as jnp
        if self.packed is None:
            self.packed = T.pack_trees(self.trees)
        return T.predict_jax(self.packed, jnp.asarray(x))


def fit_forest(x: np.ndarray, y: np.ndarray, n_trees: int = 40,
               max_depth: int = 7, min_leaf: int = 8,
               max_features: int | None = None,
               seed: int = 0) -> RandomForest:
    """y: binary {0,1}; trees regress the class mean (== probability)."""
    rng = np.random.default_rng(seed)
    if max_features is None:
        max_features = max(1, int(np.sqrt(x.shape[1])))
    forest = []
    n = len(y)
    for i in range(n_trees):
        idx = rng.integers(0, n, n)                  # bootstrap
        forest.append(T.fit_tree(x[idx], y[idx].astype(np.float32),
                                 max_depth=max_depth, min_leaf=min_leaf,
                                 max_features=max_features,
                                 rng=np.random.default_rng(seed + 100 + i)))
    return RandomForest(forest)
