"""RandomForest classifier — Pond's latency-insensitivity model core (§5).

Bootstrap + per-split feature subsampling over trees.py CART; predicted
probability = ensemble mean of leaf class fractions.  Inference available
in numpy and packed-JAX form.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.predictors import trees as T


@dataclasses.dataclass
class RandomForest:
    trees: list
    packed: dict | None = None

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(x) for t in self.trees], axis=0)

    def predict_proba_batch(self, x: np.ndarray) -> np.ndarray:
        """Batched probabilities whose row ``i`` is BIT-IDENTICAL to
        ``predict_proba(x[i:i+1])[0]``.

        ``predict_proba`` on a one-row batch reduces a contiguous
        ``(T, 1)`` float32 column, which numpy sums pairwise; the same
        reduction over a ``(T, N)`` batch runs the strided sequential
        loop instead and can differ in the last ulp.  Reducing the
        TRANSPOSED (row-contiguous) stack restores the pairwise order
        per row, so the compiled policy engine can score every VM in
        one call and still match the scalar control plane's per-VM
        probabilities bit-for-bit (asserted in tests/test_predictors).
        """
        preds = T.predict_stack(self.trees, x)        # (T, N)
        return np.mean(np.ascontiguousarray(preds.T), axis=1)

    def predict_proba_jax(self, x):
        import jax.numpy as jnp
        if self.packed is None:
            self.packed = T.pack_trees(self.trees)
        return T.predict_jax(self.packed, jnp.asarray(x))


def fit_forest(x: np.ndarray, y: np.ndarray, n_trees: int = 40,
               max_depth: int = 7, min_leaf: int = 8,
               max_features: int | None = None,
               seed: int = 0) -> RandomForest:
    """y: binary {0,1}; trees regress the class mean (== probability)."""
    rng = np.random.default_rng(seed)
    if max_features is None:
        max_features = max(1, int(np.sqrt(x.shape[1])))
    forest = []
    n = len(y)
    for i in range(n_trees):
        idx = rng.integers(0, n, n)                  # bootstrap
        forest.append(T.fit_tree(x[idx], y[idx].astype(np.float32),
                                 max_depth=max_depth, min_leaf=min_leaf,
                                 max_features=max_features,
                                 rng=np.random.default_rng(seed + 100 + i)))
    return RandomForest(forest)
