"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None, scale: float | None = None):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D). fp32 softmax, GQA by repeat."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
