"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

Grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the TPU grid executes
minor-to-major sequentially, so the (m, l, acc) running-softmax state lives
in VMEM scratch that persists across the kv-block dimension.  BlockSpecs
tile q/k/v into (block_q x head_dim) / (block_k x head_dim) VMEM windows —
the (Sq, Skv) logits matrix never exists in HBM.

MXU alignment: block_q/block_k default to 512/512 and head_dim is padded
to a lane multiple (128) by ops.py before the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int | None, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, scale: float, causal: bool = True,
                           window: int | None = None, block_q: int = 512,
                           block_k: int = 512, interpret: bool = False):
    """q: (B, Hq, Sq, D); k,v: (B, Hkv, Skv, D) — head-major layout.
    Returns (B, Hq, Sq, D).  Sq/Skv padded to block multiples by caller."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0
    grid = (b, hq, sq // bq, skv // bk)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, window=window, kv_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
