"""jit'd public wrapper for the flash-attention kernel.

Layout adaptation (B,S,H,D) -> head-major (B,H,S,D), head_dim padding to
the 128-lane TPU tile, sequence padding to block multiples, and backend
dispatch: the Pallas kernel on TPU (or interpret=True for CPU validation),
the custom-VJP blocked implementation elsewhere (identical math).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if interpret is None:
        if jax.default_backend() != "tpu":
            from repro.models.attention import blocked_attention
            b, sq = q.shape[0], q.shape[1]
            skv = k.shape[1]
            pos_q = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
            pos_k = jnp.broadcast_to(jnp.arange(skv)[None], (b, skv))
            return blocked_attention(q, k, v, scale, pos_q, pos_k,
                                     window=window, causal=causal,
                                     block_k=block_k)
        interpret = False

    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dp = (-d) % 128
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    sqp = (-sq) % bq
    skp = (-skv) % bk

    def prep(t, seq_pad):
        t = jnp.moveaxis(t, 2, 1)                     # (B,H,S,D)
        return jnp.pad(t, ((0, 0), (0, 0), (0, seq_pad), (0, dp)))
    qh = prep(q, sqp)
    kh = prep(k, skp)
    vh = prep(v, skp)
    out = K.flash_attention_kernel(qh, kh, vh, scale=scale, causal=causal,
                                   window=window, block_q=bq, block_k=bk,
                                   interpret=interpret)
    out = out[:, :, :sq, :d]
    return jnp.moveaxis(out, 1, 2)
