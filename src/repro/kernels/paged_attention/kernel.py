"""Pallas TPU paged decode-attention kernel over a block-table KV pool.

This is the TPU-native reinterpretation of Pond's CXL ld/st pool access
(DESIGN.md §6): the KV cache is a pool of fixed-size *pages* (the 1GB-slice
analogue at KV-block granularity); each sequence owns a page list (block
table).  The kernel sees ONE logical pool array — tier placement (HBM-local
vs host-pool, with the runtime staging pool pages via async copies) is a
memory-space concern of serving/kv_cache.py, not of the kernel, exactly
like Pond hides pool topology behind HDM decoding.

Grid = (batch, kv_heads, pages_per_seq); the block table is a
scalar-prefetch operand so the page BlockSpec index_map can gather the
right page into VMEM; running-softmax state lives in VMEM scratch across
the page dimension (TPU sequential grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(table_ref, lens_ref, q_ref, kp_ref, vp_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
            pages_per_seq: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = lens_ref[b]

    # pages entirely beyond seq_len are padding (block table fills with
    # page 0): their logits would be fully masked anyway, so skip the two
    # dot-products and the softmax update outright.
    @pl.when(pi * page_size < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, d)
        k = kp_ref[0, 0].astype(jnp.float32)          # (page, d)
        v = vp_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)

        pos = pi * page_size + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(pos < seq_len, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, block_table, seq_lens, *,
                           scale: float, interpret: bool = False):
    """Single-token decode attention over paged KV.

    q:           (B, Hq, D)             current-token queries
    k_pages:     (Hkv, num_pages, page_size, D)  unified two-tier pool
    v_pages:     (Hkv, num_pages, page_size, D)
    block_table: (B, pages_per_seq) int32 page ids (padded with 0)
    seq_lens:    (B,) int32
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    g = hq // hkv
    pages_per_seq = block_table.shape[1]
    grid = (b, hkv, pages_per_seq)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_kernel, scale=scale, page_size=page_size,
                               pages_per_seq=pages_per_seq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # block_table, seq_lens
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h, pi, tbl, lens: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, pi, tbl, lens: (h, tbl[b_, pi], 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, pi, tbl, lens: (h, tbl[b_, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, pi, tbl, lens: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
