"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens, *,
                        scale: float):
    """Same contract as kernel.paged_attention_kernel."""
    b, hq, d = q.shape
    hkv, npg, page, _ = k_pages.shape
    g = hq // hkv
    ppseq = block_table.shape[1]
    # gather each sequence's pages: (B, Hkv, ppseq*page, D)
    k_seq = jnp.moveaxis(k_pages[:, block_table], 0, 2)   # (B,ppseq,Hkv,pg,D)
    v_seq = jnp.moveaxis(v_pages[:, block_table], 0, 2)
    k_seq = k_seq.transpose(0, 2, 1, 3, 4).reshape(b, hkv, ppseq * page, d)
    v_seq = v_seq.transpose(0, 2, 1, 3, 4).reshape(b, hkv, ppseq * page, d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg,
                        k_seq.astype(jnp.float32)) * scale
    valid = jnp.arange(ppseq * page)[None] < seq_lens[:, None]
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_seq.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
