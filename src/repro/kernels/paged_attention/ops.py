"""jit'd wrapper for paged decode attention with backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention import ref as R


def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    scale: float | None = None,
                    interpret: bool | None = None):
    """q: (B,Hq,D); pages: (Hkv,P,page,D); table: (B,ppseq); lens: (B,)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if interpret is None:
        if jax.default_backend() != "tpu":
            # jnp oracle IS the lowering on non-TPU backends
            return R.paged_attention_ref(q, k_pages, v_pages, block_table,
                                         seq_lens, scale=scale)
        interpret = False
    d = q.shape[-1]
    dp = (-d) % 128
    if dp:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, dp)))
        k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, dp)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, dp)))
    out = K.paged_attention_kernel(q, k_pages, v_pages, block_table,
                                   seq_lens, scale=scale,
                                   interpret=interpret)
    return out[..., :d]
